//! Bit-packed unsigned integer arrays.
//!
//! The pocket format stores codebook indices with exactly `log2(K)` bits each
//! (Eq. 14's `log2(K)·N` term).  This module packs/unpacks b-bit values
//! (1 <= b <= 32) into a little-endian u64 word stream, processing a word at
//! a time on the hot path (see DESIGN.md §8 and `benches/perf_hotpath.rs`).

/// Immutable view over packed b-bit unsigned integers.
#[derive(Clone, Debug, PartialEq)]
pub struct BitPacked {
    bits: u32,
    len: usize,
    words: Vec<u64>,
}

impl BitPacked {
    /// Pack `values` with `bits` bits each. Every value must fit.
    pub fn pack(values: &[u32], bits: u32) -> Self {
        assert!((1..=32).contains(&bits), "bits must be in 1..=32");
        let mask = ones(bits);
        let total_bits = values.len() as u64 * bits as u64;
        let n_words = total_bits.div_ceil(64) as usize;
        let mut words = vec![0u64; n_words];
        let mut word_i = 0usize;
        let mut bit_off = 0u32;
        for &v in values {
            debug_assert!(v as u64 <= mask, "value {v} does not fit in {bits} bits");
            let v = (v as u64) & mask;
            words[word_i] |= v << bit_off;
            let used = 64 - bit_off;
            if used < bits {
                // spills into the next word
                words[word_i + 1] |= v >> used;
            }
            bit_off += bits;
            if bit_off >= 64 {
                bit_off -= 64;
                word_i += 1;
            }
        }
        BitPacked { bits, len: values.len(), words }
    }

    /// Number of packed values.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bits per value.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Exact payload size in bits (the Eq. 14 accounting term).
    pub fn payload_bits(&self) -> u64 {
        self.len as u64 * self.bits as u64
    }

    /// Random access to the i-th value.
    pub fn get(&self, i: usize) -> u32 {
        assert!(i < self.len);
        let bit = i as u64 * self.bits as u64;
        let word = (bit / 64) as usize;
        let off = (bit % 64) as u32;
        let mask = ones(self.bits);
        let lo = self.words[word] >> off;
        let v = if off + self.bits > 64 {
            lo | (self.words[word + 1] << (64 - off))
        } else {
            lo
        };
        (v & mask) as u32
    }

    /// Unpack everything (word-at-a-time fast path).
    pub fn unpack(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.len);
        let bits = self.bits;
        let mask = ones(bits);
        let mut word_i = 0usize;
        let mut bit_off = 0u32;
        for _ in 0..self.len {
            let lo = self.words[word_i] >> bit_off;
            let v = if bit_off + bits > 64 {
                lo | (self.words[word_i + 1] << (64 - bit_off))
            } else {
                lo
            };
            out.push((v & mask) as u32);
            bit_off += bits;
            if bit_off >= 64 {
                bit_off -= 64;
                word_i += 1;
            }
        }
        out
    }

    /// Unpack `count` values starting at `start` (same word-at-a-time walk
    /// as [`BitPacked::unpack`], seeded mid-stream) — the layer-streaming
    /// decode path pulls one block's index range without materializing the
    /// whole group's indices.
    pub fn unpack_range(&self, start: usize, count: usize) -> Vec<u32> {
        assert!(start + count <= self.len, "range {start}+{count} exceeds {}", self.len);
        let bits = self.bits;
        let mask = ones(bits);
        let first_bit = start as u64 * bits as u64;
        let mut word_i = (first_bit / 64) as usize;
        let mut bit_off = (first_bit % 64) as u32;
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            let lo = self.words[word_i] >> bit_off;
            let v = if bit_off + bits > 64 {
                lo | (self.words[word_i + 1] << (64 - bit_off))
            } else {
                lo
            };
            out.push((v & mask) as u32);
            bit_off += bits;
            if bit_off >= 64 {
                bit_off -= 64;
                word_i += 1;
            }
        }
        out
    }

    /// Serialize: `bits (u32) | len (u64) | words...` little-endian.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12 + self.words.len() * 8);
        out.extend_from_slice(&self.bits.to_le_bytes());
        out.extend_from_slice(&(self.len as u64).to_le_bytes());
        for w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    /// Deserialize; returns (value, bytes consumed).
    pub fn from_bytes(b: &[u8]) -> anyhow::Result<(Self, usize)> {
        anyhow::ensure!(b.len() >= 12, "bitpack header truncated");
        let bits = u32::from_le_bytes(b[0..4].try_into()?);
        anyhow::ensure!((1..=32).contains(&bits), "bad bit width {bits}");
        let len = u64::from_le_bytes(b[4..12].try_into()?) as usize;
        let n_words = (len as u64 * bits as u64).div_ceil(64) as usize;
        let need = 12 + n_words * 8;
        anyhow::ensure!(b.len() >= need, "bitpack payload truncated");
        let mut words = Vec::with_capacity(n_words);
        for i in 0..n_words {
            let o = 12 + i * 8;
            words.push(u64::from_le_bytes(b[o..o + 8].try_into()?));
        }
        Ok((BitPacked { bits, len, words }, need))
    }
}

#[inline]
fn ones(bits: u32) -> u64 {
    if bits == 64 { !0 } else { (1u64 << bits) - 1 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = Pcg32::seeded(1);
        for bits in 1..=32u32 {
            let cap = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let vals: Vec<u32> = (0..513)
                .map(|_| {
                    if cap == u32::MAX { rng.next_u32() } else { rng.below(cap + 1) }
                })
                .collect();
            let p = BitPacked::pack(&vals, bits);
            assert_eq!(p.unpack(), vals, "width {bits}");
            for (i, &v) in vals.iter().enumerate().step_by(37) {
                assert_eq!(p.get(i), v, "get width {bits}");
            }
        }
    }

    #[test]
    fn unpack_range_matches_full_unpack_at_any_offset() {
        let mut rng = Pcg32::seeded(9);
        for bits in [1u32, 7, 10, 13, 32] {
            let cap = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let vals: Vec<u32> = (0..300)
                .map(|_| {
                    if cap == u32::MAX { rng.next_u32() } else { rng.below(cap + 1) }
                })
                .collect();
            let p = BitPacked::pack(&vals, bits);
            let full = p.unpack();
            for (start, count) in [(0usize, 300usize), (0, 0), (17, 64), (64, 128), (299, 1)] {
                assert_eq!(
                    p.unpack_range(start, count),
                    full[start..start + count].to_vec(),
                    "width {bits} range {start}+{count}"
                );
            }
        }
    }

    #[test]
    fn payload_bits_exact() {
        let vals = vec![1u32; 1000];
        let p = BitPacked::pack(&vals, 10);
        assert_eq!(p.payload_bits(), 10_000);
    }

    #[test]
    fn serialization_roundtrip() {
        let mut rng = Pcg32::seeded(2);
        let vals: Vec<u32> = (0..777).map(|_| rng.below(1 << 11)).collect();
        let p = BitPacked::pack(&vals, 11);
        let bytes = p.to_bytes();
        let (q, used) = BitPacked::from_bytes(&bytes).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(p, q);
        assert_eq!(q.unpack(), vals);
    }

    #[test]
    fn truncated_input_rejected() {
        let p = BitPacked::pack(&[1, 2, 3], 8);
        let bytes = p.to_bytes();
        assert!(BitPacked::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        assert!(BitPacked::from_bytes(&bytes[..4]).is_err());
    }

    #[test]
    fn empty_is_fine() {
        let p = BitPacked::pack(&[], 7);
        assert!(p.is_empty());
        assert_eq!(p.unpack(), Vec::<u32>::new());
        let (q, _) = BitPacked::from_bytes(&p.to_bytes()).unwrap();
        assert!(q.is_empty());
    }
}
