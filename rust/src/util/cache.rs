//! `DecodeCache` — a thread-safe, shareable LRU over decoded group row
//! matrices, bounded by a **byte budget** rather than an entry count.
//!
//! Serving a pocket model means many concurrent requests touching a few
//! layer groups; the expensive unit is one backend decode of one group, and
//! the scarce resource is decoded-tensor memory.  A `DecodeCache` is keyed
//! by `(pocket_id, group)` so any number of [`crate::PocketReader`]s — and
//! any number of threads — can share one pool under one budget:
//!
//! * LRU eviction by decoded-tensor size (4 bytes per f32), never exceeding
//!   the budget; a value larger than the whole budget is served but never
//!   cached (`uncacheable` counter).
//! * **Single-flight** decode: when N threads miss on the same key at once,
//!   one computes while the rest wait and then take the cached value — each
//!   group's section is fetched and decoded exactly once.  Uncacheable
//!   work is never serialized: a zero budget skips coordination entirely,
//!   and a thread that waited once and still missed computes immediately.
//! * Counters ([`CacheStats`]) for hits, misses (= computations), LRU
//!   evictions, uncacheable inserts, resident bytes and entry count; folded
//!   into [`crate::ReaderStats`] by the readers.
//! * **Fairness accounting**: every counter is also kept per pocket id
//!   ([`TenantCacheStats`]), so a fleet of readers sharing one budget can
//!   see who hits, who decodes, and whose bytes get evicted to make room.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::tensor::TensorF32;

/// Cache key: a reader-unique pocket id plus the group name.  Ids come from
/// [`DecodeCache::next_pocket_id`], so two readers over the same container
/// bytes never alias (they share the budget, not entries).
pub type DecodeKey = (u64, String);

/// Snapshot of a cache's counters.  `misses` counts actual decode
/// computations — threads that waited on another thread's in-flight decode
/// and then took the cached value count as hits.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Values larger than the whole budget: served, never cached.
    pub uncacheable: u64,
    pub resident_bytes: u64,
    /// High-water mark of `resident_bytes` since construction or the last
    /// [`DecodeCache::reset_peak`] — the number `gen-bench` checks against
    /// the budget to prove that layer streaming really is memory-bounded.
    pub peak_resident_bytes: u64,
    pub entries: u64,
    /// Per-pocket fairness breakdown, sorted by pocket id.  When many
    /// tenants share one budget this is the evidence of who is winning:
    /// hits/misses say who the cache is serving, `evicted_bytes` says whose
    /// residency is being sacrificed to admit the others.
    pub tenants: Vec<TenantCacheStats>,
}

impl CacheStats {
    /// The fairness row for one pocket id, if that pocket has ever touched
    /// the decode path.
    pub fn tenant(&self, pocket_id: u64) -> Option<&TenantCacheStats> {
        self.tenants.iter().find(|t| t.pocket_id == pocket_id)
    }
}

/// One pocket's share of a (possibly multi-tenant) cache's activity.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantCacheStats {
    pub pocket_id: u64,
    /// Decode-path requests answered from this pocket's cached entries.
    pub hits: u64,
    /// Decode computations run on this pocket's behalf.
    pub misses: u64,
    /// Bytes of this pocket's entries pushed out — by LRU pressure (from
    /// any tenant) or by an explicit [`DecodeCache::purge_pocket`].
    pub evicted_bytes: u64,
    /// This pocket's currently resident decoded bytes.
    pub resident_bytes: u64,
}

struct Entry {
    key: DecodeKey,
    value: Arc<TensorF32>,
    bytes: u64,
}

/// Per-pocket running counters (interior, under the state lock).
#[derive(Default)]
struct Tenant {
    hits: u64,
    misses: u64,
    evicted_bytes: u64,
    resident: u64,
}

#[derive(Default)]
struct State {
    /// Most-recently-used first.
    entries: Vec<Entry>,
    resident: u64,
    /// High-water mark of `resident` (resettable via `reset_peak`).
    peak_resident: u64,
    /// In-flight decodes, for single-flight coordination.
    flights: Vec<(DecodeKey, Arc<Mutex<()>>)>,
    /// Fairness accounting per pocket id.
    tenants: std::collections::BTreeMap<u64, Tenant>,
}

impl State {
    /// Borrowed-key lookup (no allocation on the hit path), bumping the
    /// entry to most-recently-used.
    fn get_mru(&mut self, pocket: u64, group: &str) -> Option<Arc<TensorF32>> {
        let pos =
            self.entries.iter().position(|e| e.key.0 == pocket && e.key.1 == group)?;
        let e = self.entries.remove(pos);
        let v = e.value.clone();
        self.entries.insert(0, e);
        Some(v)
    }
}

static NEXT_POCKET_ID: AtomicU64 = AtomicU64::new(1);

/// Thread-safe byte-budget LRU of decoded groups.  See the module docs.
pub struct DecodeCache {
    budget: u64,
    state: Mutex<State>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    uncacheable: AtomicU64,
}

impl DecodeCache {
    /// Default budget for per-reader caches (64 MiB — every group of the
    /// bundled substrate models fits many times over).
    pub const DEFAULT_BUDGET: u64 = 64 << 20;

    /// A fresh shareable cache bounded to `bytes` of decoded tensors.  A
    /// budget of 0 disables caching entirely (every decode recomputes).
    pub fn with_budget(bytes: u64) -> Arc<DecodeCache> {
        Arc::new(DecodeCache {
            budget: bytes,
            state: Mutex::new(State::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            uncacheable: AtomicU64::new(0),
        })
    }

    /// The configured byte budget.
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Allocate a process-unique pocket id for a new reader.
    pub fn next_pocket_id() -> u64 {
        NEXT_POCKET_ID.fetch_add(1, Ordering::Relaxed)
    }

    /// Resident size of one decoded tensor (4 bytes per f32).
    pub fn tensor_bytes(t: &TensorF32) -> u64 {
        4 * t.data.len() as u64
    }

    /// Cached value for `(pocket, group)`, bumping it to most-recently-used.
    /// Prefer [`DecodeCache::get_or_try_insert_with`] on the decode path
    /// (it adds single-flight coordination and counter upkeep).
    pub fn get(&self, pocket: u64, group: &str) -> Option<Arc<TensorF32>> {
        // a pure probe: hit/miss counters track the decode path
        // (get_or_try_insert_with) only, so `misses` == decode computations
        self.state.lock().unwrap().get_mru(pocket, group)
    }

    /// The decode path: return the cached value for `(pocket, group)`, or
    /// run `f` to produce it (inserting the result under the budget).  When
    /// several threads miss on the same key concurrently, exactly one runs
    /// `f`; the others block until it finishes and then take the cached
    /// value.  A thread that waited and *still* misses (the value was too
    /// big to cache, or the decode failed) recomputes immediately instead
    /// of queueing behind further flights — uncacheable keys decode in
    /// parallel rather than serializing.
    ///
    /// Returns `(value, was_hit)` so callers can keep per-reader hit
    /// counters.  An `Err` from `f` propagates (and releases the flight so
    /// a later caller can retry).  The hit path allocates nothing.
    pub fn get_or_try_insert_with<E>(
        &self,
        pocket: u64,
        group: &str,
        f: impl FnOnce() -> Result<Arc<TensorF32>, E>,
    ) -> Result<(Arc<TensorF32>, bool), E> {
        let mut waited = false;
        loop {
            // flight coordination only pays when the computed value can be
            // cached for the waiters: a zero budget caches nothing, and a
            // thread that already waited once woke to a miss — in both
            // cases compute immediately instead of serializing
            let coordinate = self.budget > 0 && !waited;
            let wait = {
                let mut st = self.state.lock().unwrap();
                if let Some(v) = st.get_mru(pocket, group) {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    st.tenants.entry(pocket).or_default().hits += 1;
                    return Ok((v, true));
                }
                let in_flight = if coordinate {
                    st.flights
                        .iter()
                        .find(|(k, _)| k.0 == pocket && k.1 == group)
                        .map(|(_, m)| m.clone())
                } else {
                    None
                };
                match in_flight {
                    Some(m) => m,
                    None => {
                        // become a computing thread: register and lock the
                        // flight *while still holding the state lock*, so
                        // no waiter can grab the mutex first and busy-spin
                        let key: DecodeKey = (pocket, group.to_string());
                        let m = Arc::new(Mutex::new(()));
                        if coordinate {
                            st.flights.push((key.clone(), m.clone()));
                        }
                        let guard = m.lock().unwrap();
                        drop(st);
                        let result = f();
                        self.misses.fetch_add(1, Ordering::Relaxed);
                        let mut st = self.state.lock().unwrap();
                        st.tenants.entry(pocket).or_default().misses += 1;
                        if coordinate {
                            st.flights.retain(|(k, _)| *k != key);
                        }
                        let out = result.map(|v| {
                            self.insert_locked(&mut st, key, v.clone());
                            (v, false)
                        });
                        drop(st);
                        drop(guard);
                        return out;
                    }
                }
            };
            // another thread is decoding this key: wait for it, then retry
            // (hit in the common case; recompute if it was uncacheable)
            drop(wait.lock().unwrap());
            waited = true;
        }
    }

    fn insert_locked(&self, st: &mut State, key: DecodeKey, value: Arc<TensorF32>) {
        let bytes = Self::tensor_bytes(&value);
        if bytes > self.budget {
            self.uncacheable.fetch_add(1, Ordering::Relaxed);
            return;
        }
        if let Some(pos) = st.entries.iter().position(|e| e.key == key) {
            let old = st.entries.remove(pos);
            st.resident -= old.bytes;
            st.tenants.entry(old.key.0).or_default().resident -= old.bytes;
        }
        while st.resident + bytes > self.budget {
            let evicted = st.entries.pop().expect("resident bytes imply entries");
            st.resident -= evicted.bytes;
            let t = st.tenants.entry(evicted.key.0).or_default();
            t.resident -= evicted.bytes;
            t.evicted_bytes += evicted.bytes;
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        st.resident += bytes;
        st.peak_resident = st.peak_resident.max(st.resident);
        st.tenants.entry(key.0).or_default().resident += bytes;
        st.entries.insert(0, Entry { key, value, bytes });
    }

    /// Reset the resident high-water mark to the *current* residency, so a
    /// later [`DecodeCache::stats`] reports the peak of one phase rather
    /// than the cache's whole lifetime.  Multi-phase benches call this at
    /// phase boundaries.
    pub fn reset_peak(&self) {
        let mut st = self.state.lock().unwrap();
        st.peak_resident = st.resident;
    }

    /// Drop every resident entry belonging to `pocket` (a closed reader),
    /// returning the bytes freed.  Freed bytes count into the pocket's
    /// `evicted_bytes` (and the aggregate eviction counter): residency it
    /// no longer holds, whoever caused it.  The registry calls this when it
    /// evicts an idle reader so the shared budget is actually returned.
    pub fn purge_pocket(&self, pocket: u64) -> u64 {
        let mut st = self.state.lock().unwrap();
        let mut freed = 0u64;
        let mut purged = 0u64;
        st.entries.retain(|e| {
            if e.key.0 == pocket {
                freed += e.bytes;
                purged += 1;
                false
            } else {
                true
            }
        });
        st.resident -= freed;
        if freed > 0 || purged > 0 {
            let t = st.tenants.entry(pocket).or_default();
            t.resident -= freed;
            t.evicted_bytes += freed;
            self.evictions.fetch_add(purged, Ordering::Relaxed);
        }
        freed
    }

    /// Counter snapshot.
    pub fn stats(&self) -> CacheStats {
        let st = self.state.lock().unwrap();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            uncacheable: self.uncacheable.load(Ordering::Relaxed),
            resident_bytes: st.resident,
            peak_resident_bytes: st.peak_resident,
            entries: st.entries.len() as u64,
            tenants: st
                .tenants
                .iter()
                .map(|(&pocket_id, t)| TenantCacheStats {
                    pocket_id,
                    hits: t.hits,
                    misses: t.misses,
                    evicted_bytes: t.evicted_bytes,
                    resident_bytes: t.resident,
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(vals: usize) -> Arc<TensorF32> {
        Arc::new(TensorF32::new(vec![vals], vec![1.0; vals]))
    }

    fn k(id: u64, g: &str) -> DecodeKey {
        (id, g.to_string())
    }

    #[test]
    fn lru_evicts_by_bytes_not_count() {
        let c = DecodeCache::with_budget(100); // room for 25 f32s
        c.get_or_try_insert_with(1, "a", || Ok::<_, ()>(t(10))).unwrap(); // 40 B
        c.get_or_try_insert_with(1, "b", || Ok::<_, ()>(t(10))).unwrap(); // 80 B
        assert_eq!(c.stats().resident_bytes, 80);
        // touching "a" makes "b" the LRU victim
        assert!(c.get(1, "a").is_some());
        c.get_or_try_insert_with(1, "c", || Ok::<_, ()>(t(10))).unwrap(); // evicts b
        let st = c.stats();
        assert_eq!(st.evictions, 1);
        assert_eq!(st.resident_bytes, 80);
        assert_eq!(st.entries, 2);
        assert!(c.get(1, "b").is_none());
        assert!(c.get(1, "a").is_some() && c.get(1, "c").is_some());
    }

    #[test]
    fn oversize_value_is_served_but_never_cached() {
        let c = DecodeCache::with_budget(16);
        let (v, hit) = c.get_or_try_insert_with(1, "big", || Ok::<_, ()>(t(100))).unwrap();
        assert_eq!(v.data.len(), 100);
        assert!(!hit);
        let st = c.stats();
        assert_eq!((st.uncacheable, st.entries, st.resident_bytes), (1, 0, 0));
        // a second request recomputes
        let (_, hit) = c.get_or_try_insert_with(1, "big", || Ok::<_, ()>(t(100))).unwrap();
        assert!(!hit);
        assert_eq!(c.stats().misses, 2);
    }

    #[test]
    fn zero_budget_disables_caching() {
        let c = DecodeCache::with_budget(0);
        for _ in 0..3 {
            let (_, hit) = c.get_or_try_insert_with(1, "g", || Ok::<_, ()>(t(4))).unwrap();
            assert!(!hit);
        }
        let st = c.stats();
        assert_eq!((st.misses, st.hits, st.entries), (3, 0, 0));
        assert_eq!(st.uncacheable, 3);
    }

    #[test]
    fn errors_propagate_and_release_the_flight() {
        let c = DecodeCache::with_budget(1000);
        let e = c.get_or_try_insert_with(1, "g", || Err::<Arc<TensorF32>, _>("boom"));
        assert_eq!(e.unwrap_err(), "boom");
        // the key is retryable and the flight is gone
        let (_, hit) = c.get_or_try_insert_with(1, "g", || Ok::<_, ()>(t(2))).unwrap();
        assert!(!hit);
        assert!(c.get(1, "g").is_some());
    }

    #[test]
    fn replacing_a_key_adjusts_resident_bytes() {
        let c = DecodeCache::with_budget(1000);
        c.get_or_try_insert_with(1, "g", || Ok::<_, ()>(t(10))).unwrap();
        assert_eq!(c.stats().resident_bytes, 40);
        // direct re-insert path (e.g. after an uncached recompute)
        let mut st = c.state.lock().unwrap();
        c.insert_locked(&mut st, k(1, "g"), t(5));
        drop(st);
        let st = c.stats();
        assert_eq!((st.resident_bytes, st.entries), (20, 1));
    }

    #[test]
    fn single_flight_dedupes_concurrent_misses() {
        use std::sync::atomic::AtomicUsize;
        let c = DecodeCache::with_budget(1 << 20);
        let computed = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let (v, _) = c
                        .get_or_try_insert_with(7, "g", || {
                            computed.fetch_add(1, Ordering::SeqCst);
                            // widen the race window
                            std::thread::sleep(std::time::Duration::from_millis(10));
                            Ok::<_, ()>(t(16))
                        })
                        .unwrap();
                    assert_eq!(v.data.len(), 16);
                });
            }
        });
        assert_eq!(computed.load(Ordering::SeqCst), 1, "decode ran more than once");
        let st = c.stats();
        assert_eq!(st.misses, 1);
        assert_eq!(st.hits, 7);
    }

    #[test]
    fn uncacheable_keys_do_not_serialize_after_the_first_wait() {
        use std::sync::atomic::AtomicUsize;
        // budget too small to cache: every thread must end up computing,
        // and a thread that waited once must not queue behind new flights
        let c = DecodeCache::with_budget(8);
        let computed = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..6 {
                s.spawn(|| {
                    let (v, hit) = c
                        .get_or_try_insert_with(9, "g", || {
                            computed.fetch_add(1, Ordering::SeqCst);
                            std::thread::sleep(std::time::Duration::from_millis(5));
                            Ok::<_, ()>(t(16))
                        })
                        .unwrap();
                    assert!(!hit);
                    assert_eq!(v.data.len(), 16);
                });
            }
        });
        assert_eq!(computed.load(Ordering::SeqCst), 6, "every request must decode");
        let st = c.stats();
        assert_eq!((st.misses, st.hits), (6, 0));
        assert_eq!(st.uncacheable, 6);
        assert_eq!(st.entries, 0);
    }

    #[test]
    fn peak_resident_tracks_high_water_and_never_exceeds_budget() {
        let c = DecodeCache::with_budget(100); // room for 25 f32s
        c.get_or_try_insert_with(1, "a", || Ok::<_, ()>(t(12))).unwrap(); // 48 B
        c.get_or_try_insert_with(1, "b", || Ok::<_, ()>(t(12))).unwrap(); // 96 B
        assert_eq!(c.stats().peak_resident_bytes, 96);
        // evicting the 48 B "a" to admit a 40 B "c" shrinks resident, but
        // the high-water mark stays
        c.get_or_try_insert_with(1, "c", || Ok::<_, ()>(t(10))).unwrap();
        let st = c.stats();
        assert_eq!(st.evictions, 1);
        assert_eq!(st.resident_bytes, 88);
        assert_eq!(st.peak_resident_bytes, 96);
        assert!(st.peak_resident_bytes <= 100, "peak must respect the budget");
    }

    #[test]
    fn tenant_fairness_counters_split_by_pocket() {
        let c = DecodeCache::with_budget(100); // room for 25 f32s
        // tenant 1 fills most of the budget; tenant 2's insert evicts 1's
        c.get_or_try_insert_with(1, "a", || Ok::<_, ()>(t(12))).unwrap(); // 48 B
        c.get_or_try_insert_with(1, "b", || Ok::<_, ()>(t(12))).unwrap(); // 96 B
        c.get_or_try_insert_with(1, "b", || Ok::<_, ()>(t(12))).unwrap(); // hit
        c.get_or_try_insert_with(2, "z", || Ok::<_, ()>(t(12))).unwrap(); // evicts 1/"a"
        let st = c.stats();
        let t1 = *st.tenant(1).expect("tenant 1 accounted");
        let t2 = *st.tenant(2).expect("tenant 2 accounted");
        assert_eq!((t1.hits, t1.misses, t1.evicted_bytes, t1.resident_bytes), (1, 2, 48, 48));
        assert_eq!((t2.hits, t2.misses, t2.evicted_bytes, t2.resident_bytes), (0, 1, 0, 48));
        // per-tenant rows sum to the aggregates
        assert_eq!(t1.hits + t2.hits, st.hits);
        assert_eq!(t1.misses + t2.misses, st.misses);
        assert_eq!(t1.resident_bytes + t2.resident_bytes, st.resident_bytes);
    }

    #[test]
    fn reset_peak_scopes_the_high_water_mark_to_a_phase() {
        let c = DecodeCache::with_budget(1000);
        c.get_or_try_insert_with(1, "a", || Ok::<_, ()>(t(50))).unwrap(); // 200 B
        c.get_or_try_insert_with(1, "a", || Ok::<_, ()>(t(10))).unwrap(); // hit, still 200
        assert_eq!(c.stats().peak_resident_bytes, 200);
        // phase boundary: peak falls back to current residency, then only
        // new growth raises it
        let mut st = c.state.lock().unwrap();
        c.insert_locked(&mut st, k(1, "a"), t(10)); // shrink to 40 B
        drop(st);
        c.reset_peak();
        assert_eq!(c.stats().peak_resident_bytes, 40);
        c.get_or_try_insert_with(1, "b", || Ok::<_, ()>(t(20))).unwrap(); // +80 B
        assert_eq!(c.stats().peak_resident_bytes, 120);
    }

    #[test]
    fn purge_pocket_frees_budget_and_charges_the_tenant() {
        let c = DecodeCache::with_budget(1000);
        c.get_or_try_insert_with(1, "a", || Ok::<_, ()>(t(10))).unwrap(); // 40 B
        c.get_or_try_insert_with(1, "b", || Ok::<_, ()>(t(10))).unwrap(); // 40 B
        c.get_or_try_insert_with(2, "a", || Ok::<_, ()>(t(5))).unwrap(); // 20 B
        assert_eq!(c.purge_pocket(1), 80);
        let st = c.stats();
        assert_eq!((st.resident_bytes, st.entries, st.evictions), (20, 1, 2));
        let t1 = *st.tenant(1).unwrap();
        assert_eq!((t1.resident_bytes, t1.evicted_bytes), (0, 80));
        assert!(c.get(1, "a").is_none() && c.get(1, "b").is_none());
        assert!(c.get(2, "a").is_some(), "other tenants' entries survive a purge");
        assert_eq!(c.purge_pocket(1), 0, "purging an empty pocket is a no-op");
    }

    #[test]
    fn pocket_ids_are_unique_and_isolate_readers() {
        let a = DecodeCache::next_pocket_id();
        let b = DecodeCache::next_pocket_id();
        assert_ne!(a, b);
        let c = DecodeCache::with_budget(1000);
        c.get_or_try_insert_with(a, "g", || Ok::<_, ()>(t(3))).unwrap();
        assert!(c.get(b, "g").is_none(), "keys must not alias across pockets");
    }
}
