//! Tiny CLI argument parser (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positionals; typed
//! getters with defaults; and a generated usage string.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — `flag_names` are boolean.
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I, flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    let v = iter
                        .next()
                        .with_context(|| format!("option --{body} expects a value"))?;
                    out.opts.insert(body.to_string(), v);
                }
            } else {
                out.pos.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments after the subcommand.
    pub fn parse_env(skip: usize, flag_names: &[&str]) -> Result<Args> {
        Self::parse_from(std::env::args().skip(skip), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}={v} is not an integer")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}={v} is not an integer")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}={v} is not a number")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.pos
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        match self.get(name) {
            Some(v) => Ok(v),
            None => bail!("missing required option --{name}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str], flags: &[&str]) -> Args {
        Args::parse_from(xs.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn parses_options_and_flags() {
        let a = args(&["--steps", "100", "--preset=p8x", "--verbose", "input.bin"], &["verbose"]);
        assert_eq!(a.usize_or("steps", 1).unwrap(), 100);
        assert_eq!(a.str_or("preset", "x"), "p8x");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.positional(), &["input.bin".to_string()]);
    }

    #[test]
    fn defaults_kick_in() {
        let a = args(&[], &[]);
        assert_eq!(a.usize_or("steps", 42).unwrap(), 42);
        assert_eq!(a.f64_or("lr", 0.5).unwrap(), 0.5);
        assert!(a.require("x").is_err());
    }

    #[test]
    fn missing_value_is_error() {
        let r = Args::parse_from(vec!["--steps".to_string()], &[]);
        assert!(r.is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = args(&["--steps", "abc"], &[]);
        assert!(a.usize_or("steps", 1).is_err());
    }
}
