//! IEEE-754 binary16 conversion (no `half` crate offline).
//!
//! The pocket file format stores codebooks in f16 (Eq. 14: `16·K·d` bits),
//! so the round-trip here is on the serving path of every decompression.

/// Convert f32 -> f16 bits with round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    // Re-bias exponent: f32 bias 127 -> f16 bias 15.
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal f16.
        let e = (unbiased + 15) as u32;
        let m = mant >> 13;
        let rest = mant & 0x1fff;
        let mut out = (sign as u32) | (e << 10) | m;
        // round to nearest even
        if rest > 0x1000 || (rest == 0x1000 && (m & 1) == 1) {
            out += 1; // may carry into exponent; that is correct rounding
        }
        return out as u16;
    }
    if unbiased >= -25 {
        // Subnormal f16.
        let full_mant = mant | 0x0080_0000; // implicit leading 1
        let shift = (-14 - unbiased + 13) as u32;
        let m = full_mant >> shift;
        let rest = full_mant & ((1 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let mut out = (sign as u32) | m;
        if rest > halfway || (rest == halfway && (m & 1) == 1) {
            out += 1;
        }
        return out as u16;
    }
    sign // underflow -> signed zero
}

/// Convert f16 bits -> f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = match (exp, mant) {
        (0, 0) => sign,
        (0, m) => {
            // subnormal: normalize
            // top set bit at position h (h<10): lead = 9 - h
            let lead = m.leading_zeros() - 22;
            let m2 = (m << (lead + 1)) & 0x3ff;
            let e = 127 - 15 - lead;
            sign | (e << 23) | (m2 << 13)
        }
        (0x1f, 0) => sign | 0x7f80_0000,
        (0x1f, m) => sign | 0x7f80_0000 | (m << 13),
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// Quantize a slice through f16 and back (what the codebook experiences).
pub fn roundtrip_f16(xs: &[f32]) -> Vec<f32> {
    xs.iter().map(|&x| f16_bits_to_f32(f32_to_f16_bits(x))).collect()
}

/// Encode a slice to raw little-endian f16 bytes.
pub fn encode_f16(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 2);
    for &x in xs {
        out.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
    }
    out
}

/// Decode raw little-endian f16 bytes to f32.
pub fn decode_f16(bytes: &[u8]) -> Vec<f32> {
    assert!(bytes.len() % 2 == 0);
    bytes
        .chunks_exact(2)
        .map(|b| f16_bits_to_f32(u16::from_le_bytes([b[0], b[1]])))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_values() {
        for &(f, h) in &[
            (0.0f32, 0x0000u16),
            (1.0, 0x3c00),
            (-1.0, 0xbc00),
            (2.0, 0x4000),
            (0.5, 0x3800),
            (65504.0, 0x7bff), // f16 max
        ] {
            assert_eq!(f32_to_f16_bits(f), h, "{f}");
            assert_eq!(f16_bits_to_f32(h), f, "{h:#x}");
        }
    }

    #[test]
    fn overflow_to_inf_and_nan() {
        assert_eq!(f32_to_f16_bits(1e6), 0x7c00);
        assert_eq!(f32_to_f16_bits(-1e6), 0xfc00);
        assert!(f16_bits_to_f32(0x7c00).is_infinite());
        let nan = f32_to_f16_bits(f32::NAN);
        assert!(f16_bits_to_f32(nan).is_nan());
    }

    #[test]
    fn subnormal_roundtrip() {
        let tiny = 3.0e-7f32; // subnormal in f16
        let rt = f16_bits_to_f32(f32_to_f16_bits(tiny));
        assert!((rt - tiny).abs() / tiny < 0.1);
    }

    #[test]
    fn relative_error_bounded_for_weights() {
        // Typical LLM weight range: the f16 relative error must be < 2^-10.
        let mut x = -0.2f32;
        while x < 0.2 {
            if x.abs() > 1e-4 {
                let rt = f16_bits_to_f32(f32_to_f16_bits(x));
                assert!(((rt - x) / x).abs() < 1.0 / 1024.0, "{x} -> {rt}");
            }
            x += 1.3e-4;
        }
    }

    #[test]
    fn encode_decode_bytes() {
        let xs = vec![0.1f32, -2.5, 3.75, 0.0, -0.0078];
        let back = decode_f16(&encode_f16(&xs));
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() < 2e-3, "{a} vs {b}");
        }
    }

    /// Half ULP of the f16 lattice around a finite in-range value.
    fn half_ulp_f16(x: f32) -> f32 {
        let ax = x.abs();
        if ax < 6.10352e-5 {
            // subnormal spacing is 2^-24; half of it
            0.5 * 2.0f32.powi(-24)
        } else {
            // normal: ulp = 2^(e-10) with 2^e <= |x| < 2^(e+1)
            let e = ax.log2().floor() as i32;
            0.5 * 2.0f32.powi(e - 10)
        }
    }

    #[test]
    fn property_roundtrip_within_half_ulp() {
        use crate::util::quickcheck::{prop_assert, property};
        property("f16 round-trip within half ULP", |g| {
            // sweep several magnitude regimes incl. subnormals and weights
            let scale = *g.choose(&[1e-6f32, 1e-3, 0.04, 1.0, 100.0, 30000.0]);
            let x = g.normal(scale).clamp(-65000.0, 65000.0);
            let rt = f16_bits_to_f32(f32_to_f16_bits(x));
            let err = (rt - x).abs();
            // round-to-nearest-even: error at most half the lattice spacing
            // (tiny slack for the spacing estimate at power-of-two edges)
            let bound = half_ulp_f16(x) * 1.0001 + 1e-12;
            prop_assert(err <= bound, &format!("{x} -> {rt} (err {err}, bound {bound})"))
        });
    }

    #[test]
    fn property_encode_is_monotone() {
        use crate::util::quickcheck::{prop_assert, property};
        property("f16 conversion is monotone", |g| {
            let scale = *g.choose(&[1e-5f32, 0.04, 1.0, 1000.0]);
            let a = g.normal(scale).clamp(-65000.0, 65000.0);
            let b = g.normal(scale).clamp(-65000.0, 65000.0);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let rl = f16_bits_to_f32(f32_to_f16_bits(lo));
            let rh = f16_bits_to_f32(f32_to_f16_bits(hi));
            prop_assert(rl <= rh, &format!("monotone: {lo}->{rl} vs {hi}->{rh}"))?;
            // and on non-negative values the bit patterns order as integers
            let (pl, ph) = (lo.abs().min(hi.abs()), lo.abs().max(hi.abs()));
            prop_assert(
                f32_to_f16_bits(pl) <= f32_to_f16_bits(ph),
                &format!("bit order: {pl} vs {ph}"),
            )
        });
    }

    #[test]
    fn property_decode_encode_is_identity_on_f16_lattice() {
        use crate::util::quickcheck::{prop_assert, property};
        property("f16 bits -> f32 -> bits is identity", |g| {
            // any non-NaN half value round-trips exactly through f32
            let bits = (g.int_in(0, 0xffff) as u16) & 0x7fff; // skip sign dup of NaN space
            let is_nan = (bits & 0x7c00) == 0x7c00 && (bits & 0x3ff) != 0;
            if is_nan {
                return Ok(());
            }
            for sign in [0u16, 0x8000] {
                let h = bits | sign;
                let back = f32_to_f16_bits(f16_bits_to_f32(h));
                // -0.0 and 0.0 encode distinctly; everything must be exact
                prop_assert(back == h, &format!("lattice {h:#06x} -> {back:#06x}"))?;
            }
            Ok(())
        });
    }
}
