//! Shared HTTP/1.1 server substrate on loopback `TcpListener`.
//!
//! This is the framing layer factored out of the hermetic range server
//! ([`testserver::RangeServer`](crate::util::testserver::RangeServer)) and
//! promoted so the *production* generation front end
//! ([`serve_generation`](crate::serve::serve_generation)) runs on the same
//! wire code the tests exercise: one accept loop on an ephemeral loopback
//! port, one detached handler thread per connection, keep-alive iteration
//! driven by the handler's return value.
//!
//! The split of responsibilities:
//!
//! * this module owns **framing** — reading a request head byte-exactly
//!   through `\r\n\r\n`, parsing method/path/headers/query, the accept and
//!   connection loops, and shutdown on drop;
//! * the caller owns **semantics** — the handler writes the full response
//!   (status line, headers, body, streamed or not) straight to the
//!   `TcpStream` and returns whether the connection may serve another
//!   request.
//!
//! No keep-alive header negotiation is attempted: a handler that streams an
//! unbounded body should send `Connection: close` and return `false`.

use std::io::{self, Read};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// One parsed request head: method, full path (including any query string)
/// and the header lines.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    headers: Vec<(String, String)>,
}

impl Request {
    /// Header value by case-insensitive name, whitespace-trimmed.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// The path with any `?query` suffix removed.
    pub fn route(&self) -> &str {
        match self.path.split_once('?') {
            Some((route, _)) => route,
            None => &self.path,
        }
    }

    /// The raw query string after `?`, if any.
    pub fn query(&self) -> Option<&str> {
        self.path.split_once('?').map(|(_, q)| q)
    }

    /// Value of one `key=value` query pair (no percent-decoding — our
    /// clients send plain integers, floats and commas).
    pub fn query_param(&self, key: &str) -> Option<&str> {
        self.query()?
            .split('&')
            .find_map(|kv| kv.split_once('=').filter(|(k, _)| *k == key).map(|(_, v)| v))
    }
}

/// Per-request handler: write the complete response to `stream`, return
/// whether the connection stays open for another request (keep-alive).
type Handler = dyn Fn(&Request, &mut TcpStream) -> bool + Send + Sync;

struct Shared {
    handler: Box<Handler>,
    stop: AtomicBool,
    /// Idle-socket read timeout: an open connection that sends no request
    /// head within this window is dropped, which also bounds how long a
    /// lingering connection can outlive the server.
    read_timeout: Duration,
    /// Live connection sockets (`try_clone`d handles), keyed by a
    /// per-connection id so each handler thread can retire its own entry.
    /// `stop` walks this list and `Shutdown::Both`s every socket, so idle
    /// keep-alive threads exit immediately instead of sitting out their
    /// read timeout after the server is gone.
    conns: Mutex<Vec<(u64, TcpStream)>>,
    next_conn: AtomicU64,
}

/// A loopback HTTP/1.1 server on an ephemeral port.  The accept loop and
/// every connection handler run on background threads; [`HttpServer::stop`]
/// (also run on drop) stops the accept loop, unbinds the port and shuts
/// down every live connection socket so the per-connection threads exit
/// promptly instead of lingering until the peer closes or the idle timeout
/// fires.
pub struct HttpServer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    /// Per-connection handler threads, joined on stop (finished handles
    /// are reaped opportunistically by the accept loop).
    workers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl HttpServer {
    /// Bind an ephemeral loopback port and serve every request through
    /// `handler`.  `read_timeout` bounds how long an idle keep-alive socket
    /// may sit between requests.
    pub fn bind<H>(read_timeout: Duration, handler: H) -> io::Result<HttpServer>
    where
        H: Fn(&Request, &mut TcpStream) -> bool + Send + Sync + 'static,
    {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            handler: Box::new(handler),
            stop: AtomicBool::new(false),
            read_timeout,
            conns: Mutex::new(Vec::new()),
            next_conn: AtomicU64::new(0),
        });
        let workers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept_shared = shared.clone();
        let accept_workers = workers.clone();
        let accept = std::thread::spawn(move || {
            while !accept_shared.stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let conn_shared = accept_shared.clone();
                        // track the socket so `stop` can shut it down, and
                        // the thread handle so `stop` can join it
                        let id = conn_shared.next_conn.fetch_add(1, Ordering::Relaxed);
                        if let Ok(track) = stream.try_clone() {
                            conn_shared.conns.lock().unwrap().push((id, track));
                        }
                        let worker = std::thread::spawn(move || {
                            handle_connection(stream, &conn_shared);
                            conn_shared.conns.lock().unwrap().retain(|(cid, _)| *cid != id);
                        });
                        let mut ws = accept_workers.lock().unwrap();
                        ws.retain(|h| !h.is_finished());
                        ws.push(worker);
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(HttpServer { shared, addr, accept: Some(accept), workers })
    }

    /// The bound loopback address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the server: end the accept loop (unbinding the port), shut
    /// down every live connection socket, and join every per-connection
    /// thread.  Idle keep-alive connections see their blocking read fail
    /// immediately rather than waiting out the peer or the idle timeout,
    /// so back-to-back server instances leak neither threads nor sockets.
    /// Idempotent; also runs on drop.
    pub fn stop(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            h.join().ok();
        }
        for (_, s) in self.shared.conns.lock().unwrap().drain(..) {
            s.shutdown(Shutdown::Both).ok();
        }
        let workers = std::mem::take(&mut *self.workers.lock().unwrap());
        for h in workers {
            h.join().ok();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Keep-alive loop: serve requests on one connection until the peer closes
/// it, the handler declines keep-alive, or the server is stopping.
fn handle_connection(mut stream: TcpStream, shared: &Shared) {
    // the listener is nonblocking (stop-flag polling); on Windows accepted
    // sockets inherit that flag, so reset it before blocking reads
    stream.set_nonblocking(false).ok();
    stream.set_read_timeout(Some(shared.read_timeout)).ok();
    stream.set_nodelay(true).ok();
    while !shared.stop.load(Ordering::Relaxed) {
        let head = match read_request_head(&mut stream) {
            Ok(Some(h)) => h,
            _ => return, // peer closed, timed out, or garbage
        };
        let req = match parse_request(&head) {
            Some(r) => r,
            None => return,
        };
        if !(shared.handler)(&req, &mut stream) {
            stream.shutdown(Shutdown::Both).ok();
            return;
        }
    }
}

/// Read one request head through the final `\r\n\r\n`.  `Ok(None)` on a
/// clean peer close before any bytes.
pub fn read_request_head(stream: &mut TcpStream) -> io::Result<Option<Vec<u8>>> {
    let mut head = Vec::with_capacity(256);
    let mut b = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        if head.len() > 16 << 10 {
            return Err(io::Error::other("request head too large"));
        }
        match stream.read(&mut b) {
            // clean close and mid-head truncation both end the connection
            Ok(0) => return Ok(None),
            Ok(_) => head.push(b[0]),
            Err(e) => return Err(e),
        }
    }
    Ok(Some(head))
}

/// Parse a request head into method, path and headers.  `None` for heads
/// that are not valid HTTP/1.1 (the connection is then dropped).
pub fn parse_request(head: &[u8]) -> Option<Request> {
    let text = std::str::from_utf8(head).ok()?;
    let mut lines = text.split("\r\n");
    let mut req = lines.next()?.split_whitespace();
    let method = req.next()?.to_string();
    let path = req.next()?.to_string();
    let mut headers = Vec::new();
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }
    Some(Request { method, path, headers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn parses_method_path_headers_and_query() {
        let head = b"GET /generate?prompt=1,2&seed=9 HTTP/1.1\r\nHost: x\r\nRange: bytes=0-3\r\n\r\n";
        let req = parse_request(head).unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/generate?prompt=1,2&seed=9");
        assert_eq!(req.route(), "/generate");
        assert_eq!(req.query(), Some("prompt=1,2&seed=9"));
        assert_eq!(req.query_param("prompt"), Some("1,2"));
        assert_eq!(req.query_param("seed"), Some("9"));
        assert_eq!(req.query_param("missing"), None);
        assert_eq!(req.header("range"), Some("bytes=0-3"));
        assert_eq!(req.header("RANGE"), Some("bytes=0-3"));
        assert_eq!(req.header("nope"), None);
        assert!(parse_request(b"garbage\r\n\r\n").is_none());
    }

    #[test]
    fn routes_without_query_pass_through() {
        let req = parse_request(b"HEAD /pocket HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(req.route(), "/pocket");
        assert_eq!(req.query(), None);
        assert_eq!(req.query_param("x"), None);
    }

    #[test]
    fn serves_keep_alive_requests_until_handler_closes() {
        let srv = HttpServer::bind(Duration::from_secs(5), |req, stream| {
            let body = format!("echo {}", req.route());
            let keep = req.query_param("close").is_none();
            let conn = if keep { "keep-alive" } else { "close" };
            let head = format!(
                "HTTP/1.1 200 OK\r\nConnection: {conn}\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            stream.write_all(head.as_bytes()).is_ok() && keep
        })
        .unwrap();

        let mut s = TcpStream::connect(srv.addr()).unwrap();
        for i in 0..2 {
            s.write_all(format!("GET /r{i} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes()).unwrap();
            let mut buf = [0u8; 256];
            let n = s.read(&mut buf).unwrap();
            let text = String::from_utf8_lossy(&buf[..n]).into_owned();
            assert!(text.contains(&format!("echo /r{i}")), "{text}");
        }
        // the handler declines keep-alive on ?close=1 and the server
        // shuts the socket down after responding
        s.write_all(b"GET /last?close=1 HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut rest = Vec::new();
        s.read_to_end(&mut rest).unwrap();
        let text = String::from_utf8_lossy(&rest).into_owned();
        assert!(text.contains("echo /last"), "{text}");

        // dropping the server joins the accept loop and unbinds the port
        // (another test may immediately reuse it, so no connect assertion)
        drop(srv);
    }

    #[test]
    fn stop_shuts_down_idle_keep_alive_connections_promptly() {
        // a long idle timeout: without active shutdown the per-connection
        // thread (and the peer's read) would sit here for the full minute
        let mut srv = HttpServer::bind(Duration::from_secs(60), |_req, stream| {
            stream
                .write_all(b"HTTP/1.1 200 OK\r\nContent-Length: 2\r\n\r\nok")
                .is_ok()
        })
        .unwrap();

        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.write_all(b"GET /r HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut buf = [0u8; 128];
        let n = s.read(&mut buf).unwrap();
        assert!(String::from_utf8_lossy(&buf[..n]).contains("ok"));

        // the connection now idles in keep-alive; stop must tear it down
        // (and join its thread) without waiting out the read timeout
        let t0 = std::time::Instant::now();
        srv.stop();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let eof = matches!(s.read(&mut buf), Ok(0) | Err(_));
        assert!(eof, "peer socket must be shut down by stop");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "stop took {:?} — it must not wait for the idle timeout",
            t0.elapsed()
        );
        // stop is idempotent and drop after stop is a no-op
        srv.stop();
    }
}
