//! Minimal JSON parser + writer (no serde offline).
//!
//! Used to read `artifacts/manifest.json` (the L2->L3 shape contract) and to
//! write machine-readable bench results.  Supports the full JSON grammar
//! except for exotic number forms; numbers parse to f64 and integers are
//! recovered via [`Json::as_i64`].

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let x = self.as_f64()?;
        if x.fract() != 0.0 {
            bail!("not an integer: {x}");
        }
        Ok(x as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(usize::try_from(self.as_i64()?)?)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// Convenience: `obj.path(&["a","b","c"])`.
    pub fn path(&self, keys: &[&str]) -> Result<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k).with_context(|| format!("path {keys:?}"))?;
        }
        Ok(cur)
    }

    pub fn usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs for completeness.
                            let ch = if (0xd800..0xdc00).contains(&cp) {
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let hex2 = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                self.i += 4;
                                char::from_u32(0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00))
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("bad unicode escape"))?);
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // push raw UTF-8 byte run
                    let start = self.i - 1;
                    while self.i < self.b.len() && self.b[self.i] != b'"' && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number {s:?}"))?))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

/// Incremental JSON writer for bench reports (values escape correctly;
/// numbers render with enough digits to round-trip).
pub fn write_json(v: &Json) -> String {
    let mut s = String::new();
    emit(v, &mut s);
    s
}

fn emit(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 9e15 {
                let _ = write!(out, "{}", *x as i64);
            } else {
                let _ = write!(out, "{x}");
            }
        }
        Json::Str(s) => emit_str(s, out),
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                emit(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                emit_str(k, out);
                out.push(':');
                emit(x, out);
            }
            out.push('}');
        }
    }
}

fn emit_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers for report writing.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr(xs: Vec<Json>) -> Json {
    Json::Arr(xs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let j = Json::parse(r#"{"a": 1, "b": [true, null, "x\n"], "c": -2.5e2}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_i64().unwrap(), 1);
        assert_eq!(j.get("c").unwrap().as_f64().unwrap(), -250.0);
        let b = j.get("b").unwrap().as_arr().unwrap();
        assert_eq!(b[0], Json::Bool(true));
        assert_eq!(b[2].as_str().unwrap(), "x\n");
    }

    #[test]
    fn parse_nested_and_path() {
        let j = Json::parse(r#"{"x": {"y": {"z": [1,2,3]}}}"#).unwrap();
        assert_eq!(j.path(&["x", "y", "z"]).unwrap().usize_arr().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn writer_roundtrip() {
        let j = obj(vec![
            ("name", s("table \"1\"")),
            ("vals", arr(vec![num(1.0), num(2.5), Json::Null])),
            ("flag", Json::Bool(false)),
        ]);
        let text = write_json(&j);
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let j = Json::parse(&text).unwrap();
            assert_eq!(j.get("version").unwrap().as_i64().unwrap(), 1);
            assert!(j.get("artifacts").unwrap().as_obj().unwrap().len() > 50);
        }
    }
}
