//! From-scratch substrates (the offline vendor set has no rand / serde /
//! clap / tokio / criterion / proptest — each is re-implemented here at the
//! scope this project needs; see DESIGN.md §7).

pub mod benchlib;
pub mod bitpack;
pub mod cache;
pub mod cli;
pub mod f16;
pub mod httpserver;
pub mod json;
pub mod prng;
pub mod quickcheck;
pub mod stats;
pub mod testserver;
pub mod threadpool;
