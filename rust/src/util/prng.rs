//! Deterministic PRNG substrate (no `rand` crate offline): PCG32 with
//! Box-Muller gaussians, Fisher-Yates shuffling and a Zipf sampler.
//!
//! Every stochastic component of the pipeline (corpus generation, parameter
//! init, batch sampling, codebook init) takes an explicit [`Pcg32`] so runs
//! are reproducible from a single seed recorded in the reports.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small, fast, statistically solid.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id (any values are fine).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive an independent child generator (for per-job streams).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        let s = (self.next_u32() as u64) << 32 | self.next_u32() as u64;
        Pcg32::new(s ^ tag.wrapping_mul(0x9e3779b97f4a7c15), tag | 1)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 > 1e-12 {
                let u2 = self.next_f32();
                let r = (-2.0 * (u1 as f64).ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2 as f64).cos()) as f32;
            }
        }
    }

    /// Fill a slice with N(0, std) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Zipf(s) sampler over {0, .., n-1} via precomputed CDF inversion.
/// Used by the synthetic corpus to get a natural-language-like token
/// frequency profile.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg32) -> usize {
        let u = rng.next_f64();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_f32_in_range_and_centered() {
        let mut rng = Pcg32::seeded(3);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Pcg32::seeded(4);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 600, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(5);
        let n = 50_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(6);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_is_monotone_decreasing_in_rank() {
        let mut rng = Pcg32::seeded(8);
        let z = Zipf::new(50, 1.1);
        let mut counts = vec![0u32; 50];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[1] > counts[25]);
        assert!(counts[0] as f64 / counts[9] as f64 > 3.0);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Pcg32::seeded(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
