//! Mini property-based testing framework (proptest is unavailable offline).
//!
//! Provides seeded generators, a configurable case count, and greedy input
//! shrinking for the common shapes we need (integers, f32 vectors, index
//! vectors).  Used across the crate for invariants like "bitpack roundtrips",
//! "pocket file format roundtrips", "k-means never increases the objective".
//!
//! Usage:
//! ```ignore
//! property("pack/unpack", |g| {
//!     let bits = g.int_in(1, 24) as u32;
//!     let xs = g.vec_u32(0..1 << bits, 0..2000);
//!     prop_assert(BitPacked::pack(&xs, bits).unpack() == xs, "roundtrip")
//! });
//! ```

use super::prng::Pcg32;

/// Per-case random input source with range helpers.
pub struct Gen {
    rng: Pcg32,
    /// Shrink pressure in [0,1]: generators scale sizes down as it rises.
    pub scale: f64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Pcg32::seeded(seed), scale: 1.0 }
    }

    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo);
        let span = (hi - lo) as u64 + 1;
        let scaled = ((span as f64 * self.scale).ceil() as u64).max(1);
        lo + (self.rng.next_u64() % scaled.min(span)) as i64
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.int_in(lo as i64, hi as i64) as usize
    }

    /// Unsigned range helper for byte offsets/lengths (chunk sizes, prefetch
    /// windows) that exceed `int_in`'s i64 domain.  Scales down under shrink
    /// pressure like every other generator.
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi >= lo);
        let span = (hi - lo).saturating_add(1);
        let scaled = ((span as f64 * self.scale).ceil() as u64).max(1);
        lo + self.rng.next_u64() % scaled.min(span)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn normal(&mut self, std: f32) -> f32 {
        self.rng.normal() * std
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn vec_f32(&mut self, len_lo: usize, len_hi: usize, std: f32) -> Vec<f32> {
        let n = self.usize_in(len_lo, len_hi);
        (0..n).map(|_| self.normal(std)).collect()
    }

    pub fn vec_u32_below(&mut self, bound: u32, len_lo: usize, len_hi: usize) -> Vec<u32> {
        let n = self.usize_in(len_lo, len_hi);
        (0..n).map(|_| self.rng.below(bound)).collect()
    }

    /// Uniform random byte vector — adversarial raw streams for codec and
    /// framing properties (entropy coder, section payloads).
    pub fn vec_u8(&mut self, len_lo: usize, len_hi: usize) -> Vec<u8> {
        let n = self.usize_in(len_lo, len_hi);
        (0..n).map(|_| self.rng.next_u32() as u8).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u32) as usize]
    }
}

/// Outcome of one property case.
pub type PropResult = Result<(), String>;

/// Assert inside a property.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond { Ok(()) } else { Err(msg.to_string()) }
}

/// Assert approximate equality of two f32 slices.
pub fn prop_close(a: &[f32], b: &[f32], atol: f32, msg: &str) -> PropResult {
    if a.len() != b.len() {
        return Err(format!("{msg}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > atol {
            return Err(format!("{msg}: index {i}: {x} vs {y} (atol {atol})"));
        }
    }
    Ok(())
}

/// Run `cases` random cases of `prop`; on failure, retry with shrink pressure
/// to report a smaller counterexample seed. Panics with the failing seed so
/// the case is reproducible.
pub fn property_cases<F: Fn(&mut Gen) -> PropResult>(name: &str, cases: usize, prop: F) {
    for case in 0..cases {
        let seed = 0x9e3779b9u64.wrapping_mul(case as u64 + 1) ^ 0xabcdef;
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            // Greedy shrink: re-run with smaller size scales, keep the
            // smallest seed/scale that still fails.
            let mut best = (1.0f64, msg.clone());
            let mut sc = 0.5;
            while sc > 0.02 {
                let mut g2 = Gen::new(seed);
                g2.scale = sc;
                if let Err(m2) = prop(&mut g2) {
                    best = (sc, m2);
                    sc *= 0.5;
                } else {
                    break;
                }
            }
            panic!(
                "property {name:?} failed (case {case}, seed {seed:#x}, \
                 min scale {:.3}): {}",
                best.0, best.1
            );
        }
    }
}

/// 64 cases by default.
pub fn property<F: Fn(&mut Gen) -> PropResult>(name: &str, prop: F) {
    property_cases(name, 64, prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        property("reverse twice is identity", |g| {
            let xs = g.vec_f32(0, 50, 1.0);
            let mut ys = xs.clone();
            ys.reverse();
            ys.reverse();
            prop_close(&xs, &ys, 0.0, "reverse")
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        property("always fails", |_g| prop_assert(false, "nope"));
    }

    #[test]
    fn generators_respect_ranges() {
        property("ranges", |g| {
            let x = g.int_in(-5, 5);
            prop_assert((-5..=5).contains(&x), "int_in range")?;
            let u = g.usize_in(1, 3);
            prop_assert((1..=3).contains(&u), "usize_in range")?;
            let f = g.f32_in(0.0, 2.0);
            prop_assert((0.0..=2.0).contains(&f), "f32_in range")?;
            let u = g.u64_in(1 << 40, (1 << 40) + 10);
            prop_assert(((1 << 40)..=(1 << 40) + 10).contains(&u), "u64_in range")?;
            let v = g.vec_u32_below(10, 0, 20);
            prop_assert(v.iter().all(|&x| x < 10), "vec bound")?;
            let b = g.vec_u8(2, 4);
            prop_assert((2..=4).contains(&b.len()), "vec_u8 len")
        });
    }

    #[test]
    fn shrink_reduces_scale_monotonically() {
        // A property that fails only for long vectors; the shrinker should
        // still report failure (scale shrink keeps it failing until the
        // vector gets short).
        let r = std::panic::catch_unwind(|| {
            property("fails on long", |g| {
                let xs = g.vec_f32(0, 100, 1.0);
                prop_assert(xs.len() < 10, "too long")
            })
        });
        assert!(r.is_err());
    }
}
