//! Streaming statistics substrate: Welford accumulator, histogram (Fig. 2),
//! percentiles and top-k sums (the `mse_top100` metric of Tables 5-7).

/// Numerically stable streaming mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn extend(&mut self, xs: &[f32]) {
        for &x in xs {
            self.push(x as f64);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fixed-range histogram over f32 samples (Fig. 2's weight distribution).
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], underflow: 0, overflow: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n_bins = self.counts.len();
            let b = ((x - self.lo) / (self.hi - self.lo) * n_bins as f64) as usize;
            self.counts[b.min(n_bins - 1)] += 1;
        }
    }

    pub fn extend(&mut self, xs: &[f32]) {
        for &x in xs {
            self.push(x as f64);
        }
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn bin_center(&self, i: usize) -> f64 {
        self.lo + (i as f64 + 0.5) / self.counts.len() as f64 * (self.hi - self.lo)
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

/// Exact percentile by sorting a copy (fine at our sample sizes).
///
/// NaN policy: NaN samples carry no ordering information and are dropped
/// before ranking; an empty input (or one that is all NaN) returns NaN
/// rather than panicking, so a live latency report can never take down the
/// server producing it.  `p` outside `[0, 100]` is still a programmer
/// error and asserts.
pub fn percentile(xs: &[f32], p: f64) -> f32 {
    assert!((0.0..=100.0).contains(&p));
    let mut s: Vec<f32> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if s.is_empty() {
        return f32::NAN;
    }
    s.sort_by(f32::total_cmp);
    let rank = (p / 100.0 * (s.len() - 1) as f64).round() as usize;
    s[rank]
}

/// Sum of the k largest values (the paper's `mse_top100`).
///
/// NaN samples are ignored (they are neither large nor small); an empty or
/// all-NaN input sums to 0.0.
pub fn top_k_sum(xs: &[f32], k: usize) -> f64 {
    let mut s: Vec<f32> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    s.sort_by(|a, b| b.total_cmp(a));
    s.iter().take(k).map(|&x| x as f64).sum()
}

/// The symmetric range covering `frac` of the samples around zero
/// (Fig. 2 plots "values within the 99.9% range").
pub fn central_range(xs: &[f32], frac: f64) -> (f32, f32) {
    let tail = (100.0 - frac * 100.0) / 2.0;
    (percentile(xs, tail), percentile(xs, 100.0 - tail))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut w = Welford::new();
        w.extend(&xs);
        let mean: f64 = xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64;
        let var: f64 = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>()
            / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-9);
        assert!((w.var() - var).abs() < 1e-9);
        assert_eq!(w.count(), 1000);
    }

    #[test]
    fn histogram_bins_and_tails() {
        let mut h = Histogram::new(-1.0, 1.0, 4);
        for x in [-2.0, -0.9, -0.1, 0.1, 0.9, 2.0] {
            h.push(x);
        }
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.counts(), &[1, 1, 1, 1]);
        assert_eq!(h.total(), 6);
        assert!((h.bin_center(0) - (-0.75)).abs() < 1e-12);
    }

    #[test]
    fn percentile_and_topk() {
        let xs: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
        assert_eq!(top_k_sum(&xs, 3), 100.0 + 99.0 + 98.0);
    }

    #[test]
    fn percentile_and_topk_survive_nan_and_empty_input() {
        // NaN samples are dropped before ranking, never compared
        let xs = [3.0f32, f32::NAN, 1.0, 2.0, f32::NAN];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 3.0);
        assert_eq!(top_k_sum(&xs, 2), 5.0);
        // k larger than the finite sample count just sums what exists
        assert_eq!(top_k_sum(&xs, 10), 6.0);
        // empty and all-NaN inputs degrade to NaN / 0.0 instead of panicking
        assert!(percentile(&[], 50.0).is_nan());
        assert!(percentile(&[f32::NAN, f32::NAN], 50.0).is_nan());
        assert_eq!(top_k_sum(&[], 3), 0.0);
        assert_eq!(top_k_sum(&[f32::NAN], 3), 0.0);
        // infinities are ordered values and still participate
        assert_eq!(percentile(&[f32::NEG_INFINITY, 0.0, f32::INFINITY], 100.0), f32::INFINITY);
    }

    #[test]
    fn central_range_symmetricish() {
        let xs: Vec<f32> = (-500..=500).map(|i| i as f32 / 100.0).collect();
        let (lo, hi) = central_range(&xs, 0.9);
        assert!(lo < -4.0 && hi > 4.0);
        assert!((lo + hi).abs() < 0.2);
    }
}
