//! `RangeServer` — a deterministic in-process HTTP/1.1 range server on
//! loopback, so the remote streaming path
//! ([`HttpSource`](crate::packfmt::remote::HttpSource)) is exercised
//! end-to-end with **zero** network dependence: CI stays hermetic, yet every
//! byte of the wire client — request framing, `206` partial content,
//! `416` bounds, keep-alive reuse, retry and resume — runs against a real
//! `TcpListener`.
//!
//! The server serves one `&[u8]` body (a pocket container in the tests) and
//! supports:
//!
//! * `GET` with `Range: bytes=a-b` (or an open-ended `bytes=a-` / RFC 7233
//!   suffix `bytes=-n`) → `206 Partial Content` with a `Content-Range`,
//!   `GET` without a range → `200` with the whole body, `HEAD` → headers
//!   only, out-of-range or malformed ranges → `416`;
//! * **per-request logging** ([`RequestLog`]): method, path, parsed range,
//!   response status and any fault applied — tests assert on exactly what
//!   the client put on the wire;
//! * **scripted fault injection** ([`Fault`]): each queued fault is consumed
//!   by one request, in order — drop before responding, drop after K body
//!   bytes, stall past the client's read timeout, reply with an arbitrary
//!   status, or send a short body under a correct `Content-Length`.  This
//!   is what makes retry/backoff/resume behaviour *assertable*.
//!
//! Connections are keep-alive: one handler thread per connection loops over
//! requests until the peer (or a fault) closes it.  Dropping the server
//! stops the accept loop and unbinds the port.
//!
//! The accept loop, connection loop and request-head framing live in the
//! shared [`util::httpserver`](crate::util::httpserver) module (promoted
//! from here so the production generation server runs on the same wire
//! code); this module keeps only range semantics and fault injection.

use std::collections::VecDeque;
use std::io::{self, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::httpserver::{HttpServer, Request};

/// One scripted server-side failure, consumed by exactly one request.
#[derive(Clone, Copy, Debug)]
pub enum Fault {
    /// Drop the connection before sending any response bytes.
    CloseBeforeResponse,
    /// Send correct headers, then only the first K body bytes, then drop.
    DropAfter(usize),
    /// Sleep this long (past the client's read timeout), then drop without
    /// responding.
    Stall(Duration),
    /// Respond with this status code and an empty body (500/503/416/...).
    Status(u16),
    /// Send a correct `Content-Length` but K fewer body bytes, then drop.
    ShortBody(usize),
}

impl Fault {
    fn name(&self) -> &'static str {
        match self {
            Fault::CloseBeforeResponse => "close-before-response",
            Fault::DropAfter(_) => "drop-after",
            Fault::Stall(_) => "stall",
            Fault::Status(_) => "status",
            Fault::ShortBody(_) => "short-body",
        }
    }
}

/// What one request looked like on the wire, and how it was answered.
#[derive(Clone, Debug)]
pub struct RequestLog {
    pub method: String,
    pub path: String,
    /// Parsed `Range` header as `(offset, len)`, when present and valid.
    pub range: Option<(u64, u64)>,
    /// Status sent (0 when the connection was dropped before a response).
    pub status: u16,
    /// Name of the fault applied to this request, if any.
    pub fault: Option<&'static str>,
}

struct Shared {
    body: Arc<[u8]>,
    faults: Mutex<VecDeque<Fault>>,
    log: Mutex<Vec<RequestLog>>,
    /// Reject every `HEAD` with `405 Method Not Allowed` — models mirrors
    /// that only implement `GET`, so clients must length-probe with a
    /// `bytes=0-0` range request instead.
    head_405: AtomicBool,
}

/// In-process loopback HTTP/1.1 range server.  See the module docs.
pub struct RangeServer {
    shared: Arc<Shared>,
    server: HttpServer,
}

impl RangeServer {
    /// Serve `body` on an ephemeral loopback port.  The listener and every
    /// handler run on background threads; drop the server to stop.
    pub fn serve(body: impl Into<Arc<[u8]>>) -> io::Result<RangeServer> {
        let shared = Arc::new(Shared {
            body: body.into(),
            faults: Mutex::new(VecDeque::new()),
            log: Mutex::new(Vec::new()),
            head_405: AtomicBool::new(false),
        });
        let conn_shared = shared.clone();
        // a long idle timeout: pocket clients hold keep-alive connections
        // across decode gaps and must not be disconnected between fetches
        let server = HttpServer::bind(Duration::from_secs(30), move |req, stream| {
            serve_range_request(req, stream, &conn_shared)
        })?;
        Ok(RangeServer { shared, server })
    }

    /// The bound loopback address.
    pub fn addr(&self) -> SocketAddr {
        self.server.addr()
    }

    /// URL of the served container (`http://127.0.0.1:{port}/pocket`).
    pub fn url(&self) -> String {
        format!("http://127.0.0.1:{}/pocket", self.addr().port())
    }

    /// Reject every `HEAD` from now on with `405 Method Not Allowed` (a
    /// GET-only mirror).  `HEAD`s neither consume scripted faults nor serve
    /// headers; range `GET`s keep working, so a client must discover the
    /// body length via a `bytes=0-0` probe's `Content-Range`.
    pub fn disable_head(&self) {
        self.shared.head_405.store(true, Ordering::Relaxed);
    }

    /// Queue one fault; the next un-faulted request consumes it.
    pub fn push_fault(&self, fault: Fault) {
        self.shared.faults.lock().unwrap().push_back(fault);
    }

    /// Queue a whole fault schedule, consumed one fault per request.
    pub fn script_faults(&self, faults: impl IntoIterator<Item = Fault>) {
        self.shared.faults.lock().unwrap().extend(faults);
    }

    /// Faults queued but not yet consumed.
    pub fn pending_faults(&self) -> usize {
        self.shared.faults.lock().unwrap().len()
    }

    /// Every request handled so far, in arrival order.
    pub fn requests(&self) -> Vec<RequestLog> {
        self.shared.log.lock().unwrap().clone()
    }

    /// Number of requests handled so far.
    pub fn request_count(&self) -> usize {
        self.shared.log.lock().unwrap().len()
    }
}

/// Answer one framed request: consume a scripted fault (unless this is a
/// rejected `HEAD`) and respond with range semantics.
fn serve_range_request(req: &Request, stream: &mut TcpStream, shared: &Shared) -> bool {
    // a disabled-HEAD rejection is not a scripted fault: it must not
    // consume a queued fault meant for the range GETs that follow
    let head_rejected = req.method == "HEAD" && shared.head_405.load(Ordering::Relaxed);
    let fault = if head_rejected { None } else { shared.faults.lock().unwrap().pop_front() };
    respond(stream, shared, &req.method, &req.path, req.header("range"), fault)
}

/// Answer one request (applying `fault` if any); returns whether the
/// connection stays usable.
fn respond(
    stream: &mut TcpStream,
    shared: &Shared,
    method: &str,
    path: &str,
    range_header: Option<&str>,
    fault: Option<Fault>,
) -> bool {
    let total = shared.body.len() as u64;
    let range = range_header.and_then(|r| parse_range(r, total));
    let fault_name = fault.as_ref().map(Fault::name);
    let log = |status: u16| {
        shared.log.lock().unwrap().push(RequestLog {
            method: method.to_string(),
            path: path.to_string(),
            range,
            status,
            fault: fault_name,
        });
    };

    match fault {
        Some(Fault::CloseBeforeResponse) => {
            log(0);
            return false;
        }
        Some(Fault::Stall(d)) => {
            log(0);
            std::thread::sleep(d);
            return false;
        }
        Some(Fault::Status(code)) => {
            log(code);
            let head = format!(
                "HTTP/1.1 {code} Scripted Fault\r\nContent-Length: 0\r\n\r\n"
            );
            return stream.write_all(head.as_bytes()).is_ok();
        }
        _ => {}
    }

    if method == "HEAD" && shared.head_405.load(Ordering::Relaxed) {
        log(405);
        let head = "HTTP/1.1 405 Method Not Allowed\r\nAllow: GET\r\nContent-Length: 0\r\n\r\n";
        return stream.write_all(head.as_bytes()).is_ok();
    }

    // normal resolution: 416 for a present-but-invalid range, 206 for a
    // valid one, 200 for a plain GET/HEAD
    let (status, slice): (u16, &[u8]) = match (range_header, range) {
        (Some(_), None) => (416, &[]),
        (Some(_), Some((off, len))) => (206, &shared.body[off as usize..(off + len) as usize]),
        (None, _) => (200, &shared.body[..]),
    };
    log(status);

    let mut head = format!("HTTP/1.1 {status} {}\r\n", status_text(status));
    match (status, range) {
        (206, Some((off, len))) => {
            head.push_str(&format!("Content-Range: bytes {}-{}/{total}\r\n", off, off + len - 1));
        }
        (416, _) => {
            head.push_str(&format!("Content-Range: bytes */{total}\r\n"));
        }
        _ => {}
    }
    head.push_str("Accept-Ranges: bytes\r\n");
    head.push_str(&format!("Content-Length: {}\r\n\r\n", slice.len()));
    if stream.write_all(head.as_bytes()).is_err() {
        return false;
    }
    if method == "HEAD" {
        // a body-level fault on a bodiless response degrades to dropping
        // the connection after the headers — still observable by the
        // client, never a silently-eaten script entry
        return !matches!(fault, Some(Fault::DropAfter(_) | Fault::ShortBody(_)));
    }
    match fault {
        Some(Fault::DropAfter(k)) => {
            let k = k.min(slice.len());
            stream.write_all(&slice[..k]).ok();
            false
        }
        Some(Fault::ShortBody(missing)) => {
            let k = slice.len().saturating_sub(missing.max(1));
            stream.write_all(&slice[..k]).ok();
            false
        }
        _ => stream.write_all(slice).is_ok(),
    }
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        206 => "Partial Content",
        416 => "Range Not Satisfiable",
        _ => "Response",
    }
}

/// Resolve a `bytes=a-b` / `bytes=a-` / `bytes=-n` header against `total`
/// body bytes to `(offset, len)`.  `None` for malformed or unsatisfiable
/// ranges (→ 416).
fn parse_range(header: &str, total: u64) -> Option<(u64, u64)> {
    let spec = header.strip_prefix("bytes=")?;
    let (a, b) = spec.split_once('-')?;
    if a.trim().is_empty() {
        // RFC 7233 suffix range `bytes=-n`: the final n bytes, clamped to
        // the body (an over-long suffix means "the whole body").  A zero
        // or missing suffix length is unsatisfiable.
        let n: u64 = b.trim().parse().ok()?;
        if n == 0 || total == 0 {
            return None;
        }
        let len = n.min(total);
        return Some((total - len, len));
    }
    let start: u64 = a.trim().parse().ok()?;
    if start >= total {
        return None;
    }
    let end_incl: u64 = match b.trim() {
        "" => total - 1,
        s => s.parse::<u64>().ok()?.min(total - 1),
    };
    if end_incl < start {
        return None;
    }
    Some((start, end_incl - start + 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;
    use std::net::Shutdown;

    fn raw_request(addr: SocketAddr, req: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(req.as_bytes()).unwrap();
        s.shutdown(Shutdown::Write).ok();
        let mut out = Vec::new();
        s.read_to_end(&mut out).unwrap();
        // bodies are arbitrary bytes; the heads under test are ASCII
        String::from_utf8_lossy(&out).into_owned()
    }

    #[test]
    fn range_parsing_resolves_and_rejects() {
        assert_eq!(parse_range("bytes=0-9", 100), Some((0, 10)));
        assert_eq!(parse_range("bytes=90-", 100), Some((90, 10)));
        assert_eq!(parse_range("bytes=90-1000", 100), Some((90, 10)), "end clamps to body");
        assert_eq!(parse_range("bytes=100-110", 100), None, "start past end is 416");
        assert_eq!(parse_range("bytes=9-3", 100), None);
        assert_eq!(parse_range("chunks=0-9", 100), None);
        assert_eq!(parse_range("bytes=x-9", 100), None);
    }

    #[test]
    fn suffix_ranges_resolve_clamped_to_the_body() {
        // RFC 7233 `bytes=-n` means "the final n bytes"
        assert_eq!(parse_range("bytes=-10", 100), Some((90, 10)));
        assert_eq!(parse_range("bytes=-100", 100), Some((0, 100)), "exact-length suffix");
        assert_eq!(parse_range("bytes=-1000", 100), Some((0, 100)), "over-long suffix clamps");
        assert_eq!(parse_range("bytes=-1", 100), Some((99, 1)));
        assert_eq!(parse_range("bytes=-0", 100), None, "zero-length suffix is unsatisfiable");
        assert_eq!(parse_range("bytes=-", 100), None, "missing suffix length is malformed");
        assert_eq!(parse_range("bytes=-x", 100), None);
        assert_eq!(parse_range("bytes=-5", 0), None, "empty body has no suffix");
    }

    #[test]
    fn suffix_range_requests_get_206_on_the_wire() {
        // ASCII body: raw_request goes through from_utf8_lossy
        let body: Vec<u8> = (0u8..200).map(|i| b'a' + i % 26).collect();
        let srv = RangeServer::serve(body.clone()).unwrap();
        let r = raw_request(
            srv.addr(),
            "GET /pocket HTTP/1.1\r\nHost: x\r\nRange: bytes=-16\r\n\r\n",
        );
        assert!(r.starts_with("HTTP/1.1 206"), "{r}");
        assert!(r.contains("Content-Range: bytes 184-199/200"), "{r}");
        assert!(r.contains("Content-Length: 16"), "{r}");
        let body_start = r.find("\r\n\r\n").unwrap() + 4;
        assert_eq!(&r.as_bytes()[body_start..], &body[184..200], "suffix body is the final 16 bytes");

        let log = srv.requests();
        assert_eq!((log[0].status, log[0].range), (206, Some((184, 16))));
    }

    #[test]
    fn serves_200_206_416_and_head() {
        let body: Vec<u8> = (0u8..200).collect();
        let srv = RangeServer::serve(body.clone()).unwrap();

        let full = raw_request(srv.addr(), "GET /pocket HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(full.starts_with("HTTP/1.1 200"), "{full}");
        assert!(full.contains("Content-Length: 200"));

        let part = raw_request(
            srv.addr(),
            "GET /pocket HTTP/1.1\r\nHost: x\r\nRange: bytes=10-19\r\n\r\n",
        );
        assert!(part.starts_with("HTTP/1.1 206"), "{part}");
        assert!(part.contains("Content-Range: bytes 10-19/200"), "{part}");
        assert!(part.contains("Content-Length: 10"));

        let over = raw_request(
            srv.addr(),
            "GET /pocket HTTP/1.1\r\nHost: x\r\nRange: bytes=500-600\r\n\r\n",
        );
        assert!(over.starts_with("HTTP/1.1 416"), "{over}");
        assert!(over.contains("Content-Range: bytes */200"), "{over}");

        let head = raw_request(srv.addr(), "HEAD /pocket HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(head.contains("Content-Length: 200"));
        assert!(head.ends_with("\r\n\r\n"), "HEAD must carry no body: {head:?}");

        let log = srv.requests();
        assert_eq!(log.len(), 4);
        assert_eq!(log[0].status, 200);
        assert_eq!((log[1].status, log[1].range), (206, Some((10, 10))));
        assert_eq!((log[2].status, log[2].range), (416, None));
        assert_eq!((log[3].method.as_str(), log[3].status), ("HEAD", 200));
    }

    #[test]
    fn keep_alive_serves_multiple_requests_on_one_connection() {
        let srv = RangeServer::serve(vec![7u8; 64]).unwrap();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        for i in 0..3u64 {
            let req = format!(
                "GET /pocket HTTP/1.1\r\nHost: x\r\nRange: bytes={}-{}\r\n\r\n",
                i * 8,
                i * 8 + 7
            );
            s.write_all(req.as_bytes()).unwrap();
            // read the head, then exactly 8 body bytes
            let mut head = Vec::new();
            let mut b = [0u8; 1];
            while !head.ends_with(b"\r\n\r\n") {
                s.read_exact(&mut b).unwrap();
                head.push(b[0]);
            }
            let mut body = [0u8; 8];
            s.read_exact(&mut body).unwrap();
            assert_eq!(body, [7u8; 8]);
        }
        assert_eq!(srv.request_count(), 3, "all three requests rode one socket");
    }

    #[test]
    fn disabled_head_rejects_with_405_and_spares_scripted_faults() {
        let srv = RangeServer::serve(vec![3u8; 64]).unwrap();
        srv.disable_head();
        srv.push_fault(Fault::Status(500));

        let head = raw_request(srv.addr(), "HEAD /pocket HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(head.starts_with("HTTP/1.1 405"), "{head}");
        assert_eq!(srv.pending_faults(), 1, "a rejected HEAD must not eat a fault");

        // range GETs still work (after the scripted 500 is consumed)
        let r1 = raw_request(srv.addr(), "GET /pocket HTTP/1.1\r\nRange: bytes=0-0\r\n\r\n");
        assert!(r1.starts_with("HTTP/1.1 500"), "{r1}");
        let r2 = raw_request(srv.addr(), "GET /pocket HTTP/1.1\r\nRange: bytes=0-0\r\n\r\n");
        assert!(r2.starts_with("HTTP/1.1 206"), "{r2}");
        assert!(r2.contains("Content-Range: bytes 0-0/64"), "{r2}");

        let log = srv.requests();
        assert_eq!((log[0].method.as_str(), log[0].status), ("HEAD", 405));
        assert_eq!(log[0].fault, None);
    }

    #[test]
    fn faults_apply_in_script_order_then_clear() {
        let srv = RangeServer::serve(vec![1u8; 32]).unwrap();
        srv.script_faults([Fault::Status(500), Fault::CloseBeforeResponse]);
        assert_eq!(srv.pending_faults(), 2);

        let r1 = raw_request(srv.addr(), "GET /pocket HTTP/1.1\r\nRange: bytes=0-3\r\n\r\n");
        assert!(r1.starts_with("HTTP/1.1 500"), "{r1}");

        // fault 2 drops the connection with no bytes at all
        let r2 = raw_request(srv.addr(), "GET /pocket HTTP/1.1\r\nRange: bytes=0-3\r\n\r\n");
        assert!(r2.is_empty(), "close-before-response leaked bytes: {r2:?}");

        // script exhausted: back to normal service
        let r3 = raw_request(srv.addr(), "GET /pocket HTTP/1.1\r\nRange: bytes=0-3\r\n\r\n");
        assert!(r3.starts_with("HTTP/1.1 206"), "{r3}");
        assert_eq!(srv.pending_faults(), 0);

        let log = srv.requests();
        assert_eq!(log[0].fault, Some("status"));
        assert_eq!(log[1].fault, Some("close-before-response"));
        assert_eq!(log[2].fault, None);
    }

    #[test]
    fn head_with_body_fault_drops_connection_after_headers() {
        let srv = RangeServer::serve(vec![2u8; 16]).unwrap();
        srv.push_fault(Fault::ShortBody(4));
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.write_all(b"HEAD /pocket HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut head = Vec::new();
        let mut b = [0u8; 1];
        while !head.ends_with(b"\r\n\r\n") {
            s.read_exact(&mut b).unwrap();
            head.push(b[0]);
        }
        assert!(head.starts_with(b"HTTP/1.1 200"));
        // a body-level fault on a bodiless HEAD is not silently eaten: it
        // degrades to a connection drop the client can observe
        s.write_all(b"HEAD /pocket HTTP/1.1\r\nHost: x\r\n\r\n").ok();
        let mut rest = Vec::new();
        let n = s.read_to_end(&mut rest).unwrap_or(0);
        assert_eq!(n, 0, "connection must be closed after the faulted HEAD");
        assert_eq!(srv.pending_faults(), 0);
        assert_eq!(srv.requests()[0].fault, Some("short-body"));
    }

    #[test]
    fn short_body_fault_underdelivers_against_its_content_length() {
        let srv = RangeServer::serve(vec![9u8; 64]).unwrap();
        srv.push_fault(Fault::ShortBody(4));
        let r = raw_request(srv.addr(), "GET /pocket HTTP/1.1\r\nRange: bytes=0-15\r\n\r\n");
        assert!(r.starts_with("HTTP/1.1 206"), "{r}");
        assert!(r.contains("Content-Length: 16"));
        let body_start = r.find("\r\n\r\n").unwrap() + 4;
        assert_eq!(r.len() - body_start, 12, "exactly 4 bytes short");
    }
}
