//! Minimal fixed-size thread pool (no tokio/rayon offline).
//!
//! The coordinator uses this to run independent per-layer-group compression
//! jobs concurrently.  Jobs are `'static` closures; results come back over a
//! channel via [`ThreadPool::map`] which preserves input order.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("pocket-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Pool sized to the machine (at least 1, at most `cap`).
    pub fn with_cap(cap: usize) -> Self {
        let n = thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        Self::new(n.min(cap))
    }

    /// Submit a fire-and-forget job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().expect("pool alive").send(Box::new(f)).expect("workers alive");
    }

    /// Apply `f` to every item, in the pool, returning results in input order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.submit(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rrx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("all jobs returned")).collect()
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers exit their loop
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect::<Vec<i64>>(), |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<i64>>());
    }

    #[test]
    fn all_submitted_jobs_run() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn single_worker_is_sequentially_consistent() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn map_with_heavy_items() {
        let pool = ThreadPool::new(2);
        let out = pool.map(vec![vec![1u8; 1 << 16], vec![2u8; 1 << 16]], |v| v.len());
        assert_eq!(out, vec![1 << 16, 1 << 16]);
    }
}
