//! Minimal fixed-size thread pool (no tokio/rayon offline).
//!
//! Two shapes of parallelism:
//!
//! * [`scoped_map`] — fork-join over *borrowed* state (scoped threads + a
//!   shared work queue).  This is the coordinator's and reference
//!   backend's workhorse: per-group compression jobs, per-chunk decodes
//!   and matmul row splits all borrow a shared `&Runtime`/buffers, so
//!   their captures can't be `'static`.
//! * [`ThreadPool`] — long-lived workers for `'static` fire-and-forget
//!   jobs with results over a channel ([`ThreadPool::map`]); kept for
//!   daemon-style workloads that outlive a single fork-join scope.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Workers to use for fork-join loops: machine parallelism, capped.
pub fn default_workers(cap: usize) -> usize {
    thread::available_parallelism().map(|p| p.get()).unwrap_or(1).clamp(1, cap.max(1))
}

thread_local! {
    static IN_SCOPED_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// True when the current thread is a [`scoped_map`] worker.  Nested
/// fork-join callers (e.g. the reference backend's matmul row split) use
/// this to stay serial instead of oversubscribing the machine: the outer
/// fan-out already owns the cores.
pub fn in_scoped_worker() -> bool {
    IN_SCOPED_WORKER.with(|f| f.get())
}

/// Apply `f` to every item on up to `workers` scoped threads, returning
/// results in input order.  Unlike [`ThreadPool::map`], `f` and the items
/// may borrow local state (no `'static` bound); panics in `f` propagate.
/// Work is pulled from a shared queue, so uneven item costs balance out.
pub fn scoped_map<T, R, F>(workers: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let queue: Mutex<VecDeque<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect());
    let results: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
    {
        let queue = &queue;
        let results = &results;
        let f = &f;
        thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(move || {
                    IN_SCOPED_WORKER.with(|flag| flag.set(true));
                    loop {
                        let item = queue.lock().unwrap().pop_front();
                        match item {
                            Some((i, x)) => {
                                let r = f(x);
                                results.lock().unwrap()[i] = Some(r);
                            }
                            None => break,
                        }
                    }
                });
            }
        });
    }
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("scoped worker completed"))
        .collect()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("pocket-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Pool sized to the machine (at least 1, at most `cap`).
    pub fn with_cap(cap: usize) -> Self {
        let n = thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        Self::new(n.min(cap))
    }

    /// Submit a fire-and-forget job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().expect("pool alive").send(Box::new(f)).expect("workers alive");
    }

    /// Apply `f` to every item, in the pool, returning results in input order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.submit(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rrx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("all jobs returned")).collect()
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers exit their loop
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect::<Vec<i64>>(), |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<i64>>());
    }

    #[test]
    fn all_submitted_jobs_run() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn single_worker_is_sequentially_consistent() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn map_with_heavy_items() {
        let pool = ThreadPool::new(2);
        let out = pool.map(vec![vec![1u8; 1 << 16], vec![2u8; 1 << 16]], |v| v.len());
        assert_eq!(out, vec![1 << 16, 1 << 16]);
    }

    #[test]
    fn scoped_map_borrows_local_state() {
        let base = vec![10i64, 20, 30]; // borrowed, not 'static
        let out = scoped_map(4, vec![0usize, 1, 2], |i| base[i] + i as i64);
        assert_eq!(out, vec![10, 21, 32]);
    }

    #[test]
    fn scoped_map_preserves_order_with_uneven_costs() {
        let out = scoped_map(3, (0..40u64).collect::<Vec<_>>(), |x| {
            if x % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            x * x
        });
        assert_eq!(out, (0..40u64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_empty_and_single() {
        assert_eq!(scoped_map(4, Vec::<u32>::new(), |x| x), Vec::<u32>::new());
        assert_eq!(scoped_map(1, vec![5u32], |x| x + 1), vec![6]);
        assert!(default_workers(8) >= 1);
    }
}
