//! Shared fixtures for the serving-path integration suites.

use pocketllm::coordinator::lm;
use pocketllm::data::Corpus;
use pocketllm::packfmt::PocketFile;
use pocketllm::session::Session;

/// One quick two-group compression, shared across suites.  Every suite
/// builds exactly this pocket — the cross-suite bit-identity claims
/// (reader-vs-eager, remote-vs-local) rely on the fixture never diverging
/// between copies, which is why it lives here.
pub fn compressed_pocket(session: &Session) -> PocketFile {
    let corpus = Corpus::new(512, 77);
    let (ws, _) = lm::train_lm(session.runtime(), "tiny", &corpus, 6, 3, 0).unwrap();
    session
        .compress(&ws)
        .preset("p16x")
        .groups(["q", "up"])
        .steps(40)
        .kmeans_iters(1)
        .post_steps(8)
        .seed(1)
        .run()
        .unwrap()
        .pocket
}
