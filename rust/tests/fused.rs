//! Integration tests for the fused index-GEMM path — executing matmuls
//! directly on the pocket's (decoded-codeword table, bitpacked indices,
//! row scales) without materializing dense weights:
//!
//! * **property-based parity**: over random shapes / codebook sizes /
//!   chunk grids / row scales, the exact fused kernel is bit-identical to
//!   decode-then-matmul, and the reassociating accumulators (per-codeword
//!   partials, f16) stay within their documented tolerances;
//! * **end-to-end greedy identity**: generation over an "ln" pocket with
//!   `WeightRepr::Fused` streams the same tokens — and the same logits —
//!   as the dense path, while the provider holds packed forms instead of
//!   decoded rows;
//! * **packed-rln parity**: "rln" pockets (subvectors coupled through a
//!   whole-row layernorm) pack via stats-replay — the decoder re-runs per
//!   weight row with the norm reduced to stored per-row `(mean, rstd)`
//!   affines — and the fused output is bit-identical to
//!   decode-then-matmul under `FusedAcc::Exact`, both at the
//!   single-matmul level (property over random decoders / chunk grids)
//!   and end-to-end (greedy tokens + logits on an m=1 rln pocket);
//! * **chunk-aligned decode**: `decode_group_rows` rejects non-R-aligned
//!   and out-of-range row windows with typed `ShapeMismatch` errors at
//!   every boundary case.
//!
//! Everything runs hermetically on the pure-Rust reference backend.

use std::sync::Arc;

use pocketllm::coordinator::job;
use pocketllm::packfmt::PocketReader;
use pocketllm::runtime::fused::{FusedAcc, PackedGroup};
use pocketllm::runtime::reference::ops;
use pocketllm::session::Session;
use pocketllm::tensor::TensorF32;
use pocketllm::util::bitpack::BitPacked;
use pocketllm::util::quickcheck::{prop_assert, prop_close, property, property_cases};
use pocketllm::{Error, WeightProvider, WeightRepr};

mod common;
use common::compressed_pocket;

#[test]
fn fused_matmul_matches_dense_over_random_groups() {
    property("fused index-GEMM parity", |g| {
        let d = *g.choose(&[2usize, 4, 8]);
        let l = g.usize_in(1, 10);
        let k = g.usize_in(2, 24);
        let rows_total = g.usize_in(1, 40);
        let m = g.usize_in(1, 3);
        let table = g.vec_f32(k * d, k * d, 1.0);
        let mut row_scales = Vec::with_capacity(2 * rows_total);
        for _ in 0..rows_total {
            row_scales.push(g.normal(0.5)); // mean
            row_scales.push(g.f32_in(0.25, 2.0)); // std
        }
        let raw = g.vec_u32_below(k as u32, rows_total * l, rows_total * l);
        let bits = (32 - (k as u32 - 1).leading_zeros()).max(1);
        let packed = BitPacked::pack(&raw, bits);
        let group = Arc::new(
            PackedGroup::new("prop", d, l, k, rows_total, table.clone(), packed, row_scales.clone())
                .map_err(|e| e.to_string())?,
        );
        // a random row window of the group (one tensor's block slice)
        let row0 = g.usize_in(0, rows_total - 1);
        let rows = g.usize_in(1, rows_total - row0);
        let pm = group.slice(row0, rows).map_err(|e| e.to_string())?;
        // the dense W this window represents, reconstructed in the decode
        // path's op order (t * sd + mu)
        let w: Vec<f32> = (0..rows * l * d)
            .map(|j| {
                let p = row0 + j / (l * d);
                let c = raw[p * l + (j / d) % l] as usize;
                table[c * d + j % d] * row_scales[2 * p + 1] + row_scales[2 * p]
            })
            .collect();
        let mut x = g.vec_f32(m * rows, m * rows, 1.0);
        for v in x.iter_mut().step_by(5) {
            *v = 0.0; // exercise the dense kernel's zero-skip branch
        }
        let want = ops::matmul(&x, &w, m, rows, l * d);
        let got = pm.matmul(&x, m, rows, l * d);
        prop_assert(want == got, "exact accumulation must be bit-identical")?;
        let scale = want.iter().fold(1.0f32, |a, &v| a.max(v.abs()));
        prop_close(&pm.matmul_with(&x, m, FusedAcc::Partial), &want, 1e-4 * scale, "partial")?;
        prop_close(&pm.matmul_with(&x, m, FusedAcc::F16), &want, 5e-2 * scale, "f16")
    });
}

#[test]
fn fused_generation_is_bit_identical_to_dense_on_an_ln_pocket() {
    let session = Session::reference();
    let corpus = pocketllm::data::Corpus::new(512, 78);
    let (ws, _) =
        pocketllm::coordinator::lm::train_lm(session.runtime(), "tiny", &corpus, 6, 3, 0)
            .unwrap();
    let pocket = session
        .compress(&ws)
        .meta_override("w{width}_d8_k1024_m3_ln")
        .groups(["q", "up"])
        .steps(30)
        .kmeans_iters(1)
        .post_steps(5)
        .seed(2)
        .run()
        .unwrap()
        .pocket;
    let reader = Arc::new(PocketReader::from_bytes(pocket.to_bytes()).unwrap());
    let provider = session.pocket_provider(reader).unwrap();
    let prompt = vec![5i32, 1, 30, 2];
    let dense = session
        .generate(&provider)
        .prompt(prompt.clone())
        .max_new(6)
        .logits_trace(true)
        .run()
        .unwrap();
    let fused = session
        .generate(&provider)
        .prompt(prompt)
        .max_new(6)
        .logits_trace(true)
        .repr(WeightRepr::Fused)
        .run()
        .unwrap();
    assert_eq!(fused.tokens, dense.tokens, "greedy streams diverged");
    assert_eq!(fused.logits_trace, dense.logits_trace, "exact fused logits diverged");
    assert!(provider.packed_resident_bytes() > 0, "fused run must hold packed forms");
    // the packed tensors resolve and report a width matching the config
    let pm = provider.resolve_packed("b0.wq").unwrap().expect("q is ln-compressed");
    let cfg = session.manifest().lm_cfg("tiny").unwrap();
    assert_eq!(pm.width(), cfg.groups["q"].width);
    assert_eq!(pm.rows(), cfg.groups["q"].rows_per_block);
    // dense residue never packs
    assert!(provider.resolve_packed("embed").unwrap().is_none());
    assert!(provider.resolve_packed("b0.nope").unwrap().is_none());
}

#[test]
fn rln_pockets_resolve_packed_and_match_dense_bitwise() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session); // p16x => m=3 rln decoders
    let reader = Arc::new(PocketReader::from_bytes(pocket.to_bytes()).unwrap());
    let provider = session.pocket_provider(reader.clone()).unwrap();
    // whole-row coupling no longer gates packing: the stats-replay form
    // resolves, and it holds real bytes
    let pm = provider.resolve_packed("b0.wq").unwrap().expect("rln groups pack");
    assert!(provider.packed_resident_bytes() > 0);
    let cfg = session.manifest().lm_cfg("tiny").unwrap();
    assert_eq!(pm.width(), cfg.groups["q"].width);
    assert_eq!(pm.rows(), cfg.groups["q"].rows_per_block);
    // single-matmul parity: exact replay is bit-identical to the dense
    // rows the chunk decode path materializes
    let dense = provider.tensor("b0.wq").unwrap();
    let rows = pm.rows();
    let cols = pm.width();
    let mut x: Vec<f32> = (0..rows).map(|i| ((i * 37 + 11) % 19) as f32 * 0.25 - 2.0).collect();
    for v in x.iter_mut().step_by(5) {
        *v = 0.0; // exercise the zero-skip branch
    }
    let want = ops::matmul(&x, dense.as_slice(), 1, rows, cols);
    let got = pm.matmul(&x, 1, rows, cols);
    assert_eq!(want, got, "rln stats-replay diverged from decode-then-matmul");
    // dense residue still never packs, and nothing fell back to dense
    assert!(provider.resolve_packed("embed").unwrap().is_none());
    assert_eq!(reader.stats().fused_fallbacks, 0, "rln pack must not count as a fallback");
}

#[test]
fn fused_generation_is_bit_identical_to_dense_on_an_rln_pocket() {
    // The m=1 rln pair exists at both tiny group widths (w256 / w512), so
    // a two-group pocket serves every compressed tensor via stats-replay.
    let session = Session::reference();
    let corpus = pocketllm::data::Corpus::new(512, 79);
    let (ws, _) =
        pocketllm::coordinator::lm::train_lm(session.runtime(), "tiny", &corpus, 6, 3, 0)
            .unwrap();
    let pocket = session
        .compress(&ws)
        .meta_override("w{width}_d8_k1024_m1_rln")
        .groups(["q", "up"])
        .steps(25)
        .kmeans_iters(1)
        .post_steps(5)
        .seed(3)
        .run()
        .unwrap()
        .pocket;
    let reader = Arc::new(PocketReader::from_bytes(pocket.to_bytes()).unwrap());
    let provider = session.pocket_provider(reader.clone()).unwrap();
    let prompt = vec![4i32, 2, 25, 7];
    let dense = session
        .generate(&provider)
        .prompt(prompt.clone())
        .max_new(6)
        .logits_trace(true)
        .run()
        .unwrap();
    let fused = session
        .generate(&provider)
        .prompt(prompt)
        .max_new(6)
        .logits_trace(true)
        .repr(WeightRepr::Fused)
        .run()
        .unwrap();
    assert_eq!(fused.tokens, dense.tokens, "greedy streams diverged");
    assert_eq!(fused.logits_trace, dense.logits_trace, "exact rln replay logits diverged");
    assert!(provider.packed_resident_bytes() > 0, "fused run must hold packed forms");
    assert_eq!(reader.stats().fused_fallbacks, 0, "every compressed tensor must pack");
}

#[test]
fn packed_rln_matches_decode_then_matmul_over_random_decoders() {
    let session = Session::reference();
    let rt = session.runtime();
    let manifest = session.manifest();
    // m=1 twice to bias toward the cheap config; the m=3 arm covers the
    // full replay chain (hidden layers, gelu, residual) at debug speed
    let names = [
        "w256_d8_k1024_m1_rln",
        "w256_d8_k1024_m1_rln",
        "w256_d8_k512_m3_rln",
    ];
    property_cases("packed-rln exact parity", 12, |g| {
        let mc = manifest.meta_cfg(g.choose(&names)).unwrap().clone();
        let chunks = if mc.m == 1 { g.usize_in(1, 2) } else { 1 };
        let total = chunks * mc.r;
        let decoder = g.vec_f32(mc.decoder_params, mc.decoder_params, 0.3);
        let codebook = TensorF32::new(vec![mc.k, mc.d], g.vec_f32(mc.k * mc.d, mc.k * mc.d, 1.0));
        let raw = g.vec_u32_below(mc.k as u32, total * mc.l, total * mc.l);
        let mut row_scales = Vec::with_capacity(2 * total);
        for _ in 0..total {
            row_scales.push(g.normal(0.5)); // mean
            row_scales.push(g.f32_in(0.25, 2.0)); // std
        }
        let bits = (32 - (mc.k as u32 - 1).leading_zeros()).max(1);
        let packed = BitPacked::pack(&raw, bits);
        let group = Arc::new(
            job::packed_group(rt, &mc, "prop-rln", total, &decoder, &codebook, &packed, &row_scales)
                .map_err(|e| e.to_string())?,
        );
        // the dense oracle: the same sections through the chunk decode path
        let dense =
            job::decode_group_rows(rt, &mc, &decoder, &codebook, &raw, &row_scales, total, 0, total)
                .map_err(|e| e.to_string())?;
        // a random row window and a random x with zero-skip coverage
        let row0 = g.usize_in(0, total - 1);
        let rows = g.usize_in(1, total - row0);
        let pm = group.slice(row0, rows).map_err(|e| e.to_string())?;
        let m = g.usize_in(1, 2);
        let mut x = g.vec_f32(m * rows, m * rows, 1.0);
        for v in x.iter_mut().step_by(5) {
            *v = 0.0;
        }
        let wslice = &dense.data[row0 * mc.w..(row0 + rows) * mc.w];
        let want = ops::matmul(&x, wslice, m, rows, mc.w);
        let got = pm.matmul(&x, m, rows, mc.w);
        prop_assert(want == got, "exact rln replay must be bit-identical")?;
        let scale = want.iter().fold(1.0f32, |a, &v| a.max(v.abs()));
        prop_close(&pm.matmul_with(&x, m, FusedAcc::Partial), &want, 1e-3 * scale, "partial")?;
        prop_close(&pm.matmul_with(&x, m, FusedAcc::F16), &want, 5e-2 * scale, "f16")
    });
}

#[test]
fn decode_group_rows_rejects_unaligned_and_oob_ranges() {
    let session = Session::reference();
    let rt = session.runtime();
    let mc = session.manifest().meta_cfg("w256_d8_k1024_m3_ln").unwrap().clone();
    let total = 2 * mc.r;
    let decoder = vec![0.0f32; mc.decoder_params];
    let codebook = TensorF32::zeros(vec![mc.k, mc.d]);
    let indices = vec![0u32; total * mc.l];
    let scales = vec![0.0f32; 2 * total];
    let run = |row0: usize, n: usize| {
        job::decode_group_rows(rt, &mc, &decoder, &codebook, &indices, &scales, total, row0, n)
    };
    // aligned windows decode, including the boundary chunks
    assert_eq!(run(0, mc.r).unwrap().shape, vec![mc.r, mc.w]);
    assert_eq!(run(total - mc.r, mc.r).unwrap().shape, vec![mc.r, mc.w]);
    assert_eq!(run(0, total).unwrap().shape, vec![total, mc.w]);
    // misaligned start, misaligned length, both, and an aligned window
    // falling off the end: all typed ShapeMismatch
    for (row0, n) in [(1, mc.r), (0, mc.r - 1), (mc.r / 2, mc.r / 2), (mc.r, total)] {
        let e = Error::from(run(row0, n).unwrap_err());
        assert!(matches!(e, Error::ShapeMismatch { .. }), "rows {row0}+{n}: {e:?}");
    }
    // mis-sized index / scale streams are typed too
    let e = Error::from(
        job::decode_group_rows(rt, &mc, &decoder, &codebook, &indices[1..], &scales, total, 0, mc.r)
            .unwrap_err(),
    );
    assert!(matches!(e, Error::ShapeMismatch { .. }), "{e:?}");
    let e = Error::from(
        job::decode_group_rows(rt, &mc, &decoder, &codebook, &indices, &scales[2..], total, 0, mc.r)
            .unwrap_err(),
    );
    assert!(matches!(e, Error::ShapeMismatch { .. }), "{e:?}");
    // and a per-subvector decoder is required for the codeword table
    let rln = session.manifest().meta_cfg("w256_d8_k1024_m3_rln").unwrap().clone();
    let rln_decoder = vec![0.0f32; rln.decoder_params];
    let e = Error::from(
        job::decode_codeword_table(rt, &rln, &rln_decoder, &codebook).unwrap_err(),
    );
    assert!(matches!(e, Error::ShapeMismatch { .. }), "{e:?}");
}
