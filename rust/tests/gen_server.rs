//! Integration tests for the persistent generation server
//! (`serve_generation`) — the continuous-batching engine plus its loopback
//! HTTP front end:
//!
//! * **concurrent determinism**: streams served under contention, with
//!   requests joining and leaving the batch mid-flight, are bit-identical
//!   to sequential B=1 runs with the same seed/params;
//! * **backpressure**: a full per-request stream buffer parks only its own
//!   lane, and two lanes capped below their request length must overlap
//!   (`peak_batch == 2`);
//! * **client drops**: a vanished HTTP client retires its lane instead of
//!   wedging the engine;
//! * **rejection semantics**: bad prompts get a real `400`, unknown routes
//!   a `404`, and `max_new=0` an empty-but-successful stream.
//!
//! Everything runs hermetically on the pure-Rust reference backend.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Mutex;

use pocketllm::model::WeightStore;
use pocketllm::serve::{
    http_generate, http_generate_pocket, serve_generation, serve_generation_fleet, GenEngineOpts,
    GenParams,
};
use pocketllm::session::Session;
use pocketllm::util::prng::Pcg32;
use pocketllm::{InMemoryProvider, WeightProvider};

/// Send one raw HTTP request and return the whole response as text.
fn raw_http(addr: SocketAddr, req: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(req.as_bytes()).unwrap();
    let mut out = Vec::new();
    s.read_to_end(&mut out).unwrap();
    String::from_utf8_lossy(&out).into_owned()
}

#[test]
fn concurrent_http_streams_are_bit_identical_to_sequential() {
    let session = Session::reference();
    let cfg = session.manifest().lm_cfg("tiny").unwrap().clone();
    let ws = WeightStore::init(&cfg, &mut Pcg32::seeded(33));
    let provider = InMemoryProvider::new(&ws);

    // the mix: greedy and sampled requests, one private seed each
    let specs: Vec<(Vec<i32>, GenParams)> = (0..6)
        .map(|i| {
            let prompt = vec![(i * 7 + 1) as i32, (i * 3 + 2) as i32, 5];
            let (temperature, top_k) = match i % 3 {
                0 => (0.0, 0),
                1 => (0.9, 4),
                _ => (1.2, 0),
            };
            (prompt, GenParams { max_new: 5, temperature, top_k, seed: 40 + i as u64 })
        })
        .collect();

    // sequential B=1 references through the library path
    let reference: Vec<Vec<i32>> = specs
        .iter()
        .map(|(p, gp)| {
            session
                .generate(&provider)
                .prompt(p.clone())
                .max_new(gp.max_new)
                .temperature(gp.temperature)
                .top_k(gp.top_k)
                .seed(gp.seed)
                .run()
                .unwrap()
                .continuation()
                .to_vec()
        })
        .collect();

    // replay concurrently: three client threads against a batch-4 engine,
    // so batch composition shifts as requests join and finish
    let opts = GenEngineOpts { max_batch: 4, stream_capacity: 8, ..GenEngineOpts::default() };
    let (got, stats) = serve_generation(&provider, opts, |h| {
        let addr = h.addr();
        let results: Mutex<Vec<Vec<i32>>> = Mutex::new(vec![Vec::new(); specs.len()]);
        std::thread::scope(|scope| {
            for w in 0..3 {
                let specs = &specs;
                let results = &results;
                scope.spawn(move || {
                    let mut i = w;
                    while i < specs.len() {
                        let (p, gp) = &specs[i];
                        let toks = http_generate(addr, p, gp).unwrap();
                        results.lock().unwrap()[i] = toks;
                        i += 3;
                    }
                });
            }
        });
        results.into_inner().unwrap()
    })
    .unwrap();

    assert_eq!(got, reference, "concurrent streams diverged from sequential B=1");
    assert_eq!(stats.requests, 6);
    assert_eq!(stats.completed, 6);
    assert_eq!((stats.rejected, stats.dropped, stats.failed), (0, 0, 0));
    // each request is exactly prompt(3) + max_new(5) - 1 = 7 engine steps,
    // whatever the batching; batching can only shrink the step count
    assert_eq!(stats.lane_steps, 6 * 7);
    assert!(stats.steps <= stats.lane_steps, "{stats:?}");
    assert!(stats.peak_batch >= 1 && stats.peak_batch <= 4, "{stats:?}");
}

#[test]
fn fleet_routes_mixed_tenant_traffic_deterministically() {
    let session = Session::reference();
    let cfg = session.manifest().lm_cfg("tiny").unwrap().clone();
    let ws = WeightStore::init(&cfg, &mut Pcg32::seeded(37));
    let base = InMemoryProvider::new(&ws);
    // tenant "tuned" shares the base weights with a nonzero LoRA adapter
    // folded in at the provider seam: a genuinely different model
    let lora: Vec<f32> = (0..cfg.lora_layout.total)
        .map(|i| ((i * 29 + 7) % 83) as f32 / 830.0 - 0.05)
        .collect();
    let adapted = session.lora_provider(InMemoryProvider::new(&ws), lora).unwrap();
    let tenant_providers: [&dyn WeightProvider; 2] = [&base, &adapted];
    let tenant_ids = ["base", "tuned"];

    // routing is only testable if the tenants disagree — pin it on logits
    let trace = |p: &dyn WeightProvider| {
        session.generate(p).prompt(vec![1, 2, 3]).max_new(4).logits_trace(true).run().unwrap()
    };
    assert_ne!(
        trace(&base).logits_trace,
        trace(&adapted).logits_trace,
        "the adapter is a no-op; tenant routing would be untestable"
    );

    // a mixed spec: tenants interleave, greedy and sampled params
    let specs: Vec<(usize, Vec<i32>, GenParams)> = (0..6)
        .map(|i| {
            let prompt = vec![(i * 5 + 1) as i32, (i * 3 + 2) as i32, 4];
            let (temperature, top_k) = if i % 3 == 0 { (0.0, 0) } else { (0.9, 4) };
            (i % 2, prompt, GenParams { max_new: 5, temperature, top_k, seed: 60 + i as u64 })
        })
        .collect();
    let reference: Vec<Vec<i32>> = specs
        .iter()
        .map(|(t, p, gp)| {
            session
                .generate(tenant_providers[*t])
                .prompt(p.clone())
                .max_new(gp.max_new)
                .temperature(gp.temperature)
                .top_k(gp.top_k)
                .seed(gp.seed)
                .run()
                .unwrap()
                .continuation()
                .to_vec()
        })
        .collect();

    let opts = GenEngineOpts { max_batch: 4, stream_capacity: 8, ..GenEngineOpts::default() };
    let (got, stats) = serve_generation_fleet(
        &[("base", &base), ("tuned", &adapted)],
        opts,
        |h| {
            assert_eq!(h.tenants().to_vec(), vec!["base".to_string(), "tuned".to_string()]);
            // unknown ids fail typed at both the library and the HTTP seam,
            // before touching the engine
            assert!(matches!(
                h.submit_pocket("nope", vec![1], GenParams::default()),
                Err(pocketllm::Error::UnknownConfig { kind: "registered pocket", .. })
            ));
            let e = http_generate_pocket(
                h.addr(),
                "nope",
                &[1, 2],
                &GenParams { max_new: 1, ..GenParams::default() },
            )
            .unwrap_err();
            assert!(e.to_string().contains("400"), "{e}");

            // three client threads push both tenants into one shifting batch
            let addr = h.addr();
            let results: Mutex<Vec<Vec<i32>>> = Mutex::new(vec![Vec::new(); specs.len()]);
            std::thread::scope(|scope| {
                for w in 0..3 {
                    let specs = &specs;
                    let results = &results;
                    scope.spawn(move || {
                        let mut i = w;
                        while i < specs.len() {
                            let (t, p, gp) = &specs[i];
                            let toks = http_generate_pocket(addr, tenant_ids[*t], p, gp).unwrap();
                            results.lock().unwrap()[i] = toks;
                            i += 3;
                        }
                    });
                }
            });
            results.into_inner().unwrap()
        },
    )
    .unwrap();

    assert_eq!(got, reference, "fleet streams diverged from per-tenant B=1 runs");
    assert_eq!(stats.requests, 6);
    assert_eq!(stats.completed, 6);
    assert_eq!((stats.rejected, stats.dropped, stats.failed), (0, 0, 0), "{stats:?}");
    assert!(stats.peak_batch >= 1 && stats.peak_batch <= 4, "{stats:?}");

    // a duplicate tenant id is refused up front
    let e = serve_generation_fleet(&[("a", &base), ("a", &adapted)], GenEngineOpts::default(), |_| ())
        .unwrap_err();
    assert!(e.to_string().contains("duplicate"), "{e}");
}

#[test]
fn submitted_lanes_overlap_and_respect_backpressure() {
    let session = Session::reference();
    let cfg = session.manifest().lm_cfg("tiny").unwrap().clone();
    let ws = WeightStore::init(&cfg, &mut Pcg32::seeded(34));
    let provider = InMemoryProvider::new(&ws);

    let params = |seed: u64| GenParams { max_new: 6, temperature: 0.7, top_k: 3, seed };
    let prompts = [vec![1i32, 2], vec![9i32, 8, 7]];
    let reference: Vec<Vec<i32>> = prompts
        .iter()
        .zip([50u64, 51])
        .map(|(p, seed)| {
            let gp = params(seed);
            session
                .generate(&provider)
                .prompt(p.clone())
                .max_new(gp.max_new)
                .temperature(gp.temperature)
                .top_k(gp.top_k)
                .seed(gp.seed)
                .run()
                .unwrap()
                .continuation()
                .to_vec()
        })
        .collect();

    // stream_capacity 2 < max_new 6: neither lane can finish until its
    // receiver drains, and both are submitted before either is read — so
    // the two lanes MUST coexist in the batch, deterministically
    let opts = GenEngineOpts { max_batch: 4, stream_capacity: 2, ..GenEngineOpts::default() };
    let ((a, b), stats) = serve_generation(&provider, opts, |h| {
        let ra = h.submit(prompts[0].clone(), params(50));
        let rb = h.submit(prompts[1].clone(), params(51));
        let drain = |rx: std::sync::mpsc::Receiver<Result<i32, pocketllm::Error>>| {
            rx.iter().map(|r| r.unwrap()).collect::<Vec<i32>>()
        };
        (drain(ra), drain(rb))
    })
    .unwrap();

    assert_eq!(a, reference[0], "lane A diverged under backpressure");
    assert_eq!(b, reference[1], "lane B diverged under backpressure");
    assert_eq!(stats.peak_batch, 2, "lanes never overlapped: {stats:?}");
    assert_eq!(stats.completed, 2);
    assert_eq!((stats.rejected, stats.dropped, stats.failed), (0, 0, 0));
}

#[test]
fn a_vanished_client_retires_its_lane() {
    let session = Session::reference();
    let cfg = session.manifest().lm_cfg("tiny").unwrap().clone();
    let ws = WeightStore::init(&cfg, &mut Pcg32::seeded(35));
    let provider = InMemoryProvider::new(&ws);

    // 80 tokens against a 4-token stream buffer: the request cannot finish
    // without a live reader, so a dropped client must retire the lane
    let opts = GenEngineOpts { max_batch: 2, stream_capacity: 4, ..GenEngineOpts::default() };
    let ((), stats) = serve_generation(&provider, opts, |h| {
        let mut s = TcpStream::connect(h.addr()).unwrap();
        s.write_all(
            b"GET /generate?prompt=1,2&max_new=80&seed=3 HTTP/1.1\r\n\
              Host: x\r\nConnection: close\r\n\r\n",
        )
        .unwrap();
        // wait for proof the stream started, then vanish mid-stream
        let mut first = [0u8; 16];
        let n = s.read(&mut first).unwrap();
        assert!(n > 0, "no response bytes before the drop");
        drop(s);
        // serve_generation's teardown joins the engine, so the stats below
        // are final: the drop must be detected, not waited out
    })
    .unwrap();

    assert_eq!(stats.requests, 1);
    assert_eq!(stats.dropped, 1, "{stats:?}");
    assert_eq!(stats.completed, 0);
    assert!(
        (stats.lane_steps as usize) < 2 + 80,
        "engine generated the full stream for a dead client: {stats:?}"
    );
}

#[test]
fn bad_requests_get_400_and_zero_max_new_an_empty_200() {
    let session = Session::reference();
    let cfg = session.manifest().lm_cfg("tiny").unwrap().clone();
    let ws = WeightStore::init(&cfg, &mut Pcg32::seeded(36));
    let provider = InMemoryProvider::new(&ws);

    let ((), stats) = serve_generation(&provider, GenEngineOpts::default(), |h| {
        let addr = h.addr();
        // admission rejects surface as HTTP 400 with the typed message
        let e = http_generate(addr, &[], &GenParams::default()).unwrap_err();
        assert!(e.to_string().contains("400"), "{e}");
        let e = http_generate(
            addr,
            &[1, 2],
            &GenParams { max_new: 10_000, ..GenParams::default() },
        )
        .unwrap_err();
        assert!(e.to_string().contains("400"), "{e}");
        let e = http_generate(addr, &[-5], &GenParams::default()).unwrap_err();
        assert!(e.to_string().contains("400"), "{e}");

        // malformed queries are refused before they reach the engine
        let resp = raw_http(
            addr,
            "GET /generate?prompt=abc HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        let resp =
            raw_http(addr, "GET /nope HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");

        // zero tokens requested: a successful, empty stream
        let got = http_generate(
            addr,
            &[3, 1],
            &GenParams { max_new: 0, ..GenParams::default() },
        )
        .unwrap();
        assert!(got.is_empty(), "{got:?}");
    })
    .unwrap();

    // three engine-level rejects, one empty completion; the malformed
    // query and the 404 never reached the engine
    assert_eq!(stats.rejected, 3, "{stats:?}");
    assert_eq!(stats.completed, 1, "{stats:?}");
    assert_eq!(stats.requests, 1, "{stats:?}");
    assert_eq!((stats.dropped, stats.failed), (0, 0));
}
