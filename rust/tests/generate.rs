//! Integration tests for pocket-native inference — the `WeightProvider`
//! seam and the KV-cached generation loop on top of it:
//!
//! * **KV-cache parity**: incremental `gen_step` logits are bit-identical
//!   to a full-context forward pass at every step, over both
//!   `InMemoryProvider` and `PocketProvider`;
//! * **identical token streams** from eager weights, an mmap pocket and a
//!   loopback-HTTP pocket, with peak resident decoded bytes bounded by the
//!   (sub-model-size) cache budget on the pocket paths;
//! * **tensor-level resolution**: `PocketReader::tensor_chunk` decodes one
//!   block's rows bit-identically to the same rows of a whole-group
//!   decode, and chunks hit the shared cache on re-access;
//! * the provider-based perplexity agrees with the backend eval path;
//! * `ServeRequest::Generate` rides the chunk path under worker fan-out.
//!
//! Everything runs hermetically on the pure-Rust reference backend.

use std::sync::Arc;

use pocketllm::eval;
use pocketllm::model::WeightStore;
use pocketllm::packfmt::PocketReader;
use pocketllm::runtime::reference::lm::{forward_logits, gen_step, gen_step_batch, GenState};
use pocketllm::serve::ServeRequest;
use pocketllm::session::Session;
use pocketllm::util::prng::Pcg32;
use pocketllm::util::testserver::RangeServer;
use pocketllm::{InMemoryProvider, WeightProvider};

mod common;
use common::compressed_pocket;

/// Feed `tokens` one at a time; after each step, the incremental logits
/// must equal the last row of a full-context forward over that prefix —
/// exactly, not approximately.
fn assert_step_parity(provider: &dyn WeightProvider, tokens: &[i32]) {
    let cfg = provider.cfg().clone();
    let mut st = GenState::new(&cfg);
    for (t, &tok) in tokens.iter().enumerate() {
        let inc = gen_step(provider, &mut st, tok, |_| {}).unwrap();
        let s = t + 1;
        let full = forward_logits(provider, &tokens[..s], 1, s).unwrap();
        let last = &full[(s - 1) * cfg.vocab..s * cfg.vocab];
        assert_eq!(inc.as_slice(), last, "incremental logits diverged at step {t}");
    }
    assert_eq!(st.pos(), tokens.len());
    assert_eq!(st.remaining(), cfg.seq_len - tokens.len());
}

#[test]
fn incremental_logits_match_full_context_in_memory() {
    let session = Session::reference();
    let cfg = session.manifest().lm_cfg("tiny").unwrap().clone();
    let ws = WeightStore::init(&cfg, &mut Pcg32::seeded(11));
    let provider = InMemoryProvider::new(&ws);
    assert_step_parity(&provider, &[3, 1, 4, 1, 5, 9, 2, 6]);
}

#[test]
fn incremental_logits_match_full_context_over_a_pocket_provider() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let reader = Arc::new(PocketReader::from_bytes(pocket.to_bytes()).unwrap());
    let provider = session.pocket_provider(reader).unwrap();
    assert_step_parity(&provider, &[7, 0, 42, 3, 8]);
}

#[test]
fn generate_streams_identically_from_eager_mmap_and_http_pockets() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let bytes: Arc<[u8]> = pocket.to_bytes().into();
    let prompt = vec![5i32, 1, 30, 2];

    // eager ground truth: reconstruct through a reader over the serialized
    // container (the codebook goes through f16 there), then generate
    let probe = PocketReader::from_bytes(bytes.clone()).unwrap();
    let ws = session.reconstruct(&probe).unwrap();
    let mem = session.memory_provider(&ws);
    let eager = session.generate(&mem).prompt(prompt.clone()).max_new(6).run().unwrap();
    assert_eq!(eager.continuation().len(), 6);

    // the memory bound under test: ~2 layers of compressed chunks + dense
    let cfg = session.manifest().lm_cfg(probe.lm_cfg()).unwrap().clone();
    let per_layer: u64 = cfg
        .groups
        .iter()
        .filter(|(g, _)| probe.has_group(g.as_str()))
        .map(|(_, gi)| (gi.tensors.len() * gi.rows_per_block * gi.width * 4) as u64)
        .sum();
    let dense: u64 = probe.dense_names().iter().filter_map(|n| probe.section_length(n)).sum();
    let budget = 2 * per_layer + dense;

    let path = std::env::temp_dir().join("pocketllm_test_generate.pocket");
    std::fs::write(&path, &bytes[..]).unwrap();
    let mmap_reader = Arc::new(PocketReader::open(&path).unwrap().with_cache_budget(budget));
    let mmap_p = session.pocket_provider(mmap_reader.clone()).unwrap();
    let via_mmap = session.generate(&mmap_p).prompt(prompt.clone()).max_new(6).run().unwrap();
    assert_eq!(via_mmap.tokens, eager.tokens, "mmap stream diverged from eager weights");
    let st = mmap_reader.stats();
    assert!(st.chunk_decodes > 0, "pocket generation must stream chunks: {st:?}");
    assert!(
        st.cache.peak_resident_bytes <= budget,
        "memory bound violated: {st:?} (budget {budget})"
    );
    // the peak bound is cache-enforced; the meaningful half is that no
    // decoded value was too large to be accounted under the budget
    assert_eq!(st.cache.uncacheable, 0, "a decoded value bypassed the budget: {st:?}");
    std::fs::remove_file(&path).ok();

    let server = RangeServer::serve(bytes.clone()).unwrap();
    let http_reader =
        Arc::new(PocketReader::open_url(&server.url()).unwrap().with_cache_budget(budget));
    let http_p = session.pocket_provider(http_reader.clone()).unwrap();
    let via_http = session.generate(&http_p).prompt(prompt).max_new(6).run().unwrap();
    assert_eq!(via_http.tokens, eager.tokens, "http stream diverged from eager weights");
    let st = http_reader.stats();
    assert!(st.cache.peak_resident_bytes <= budget);
    assert_eq!(st.cache.uncacheable, 0, "a decoded value bypassed the budget: {st:?}");
    assert!(st.source.expect("http source reports fetch stats").bytes_fetched > 0);
}

#[test]
fn tensor_chunk_is_bit_identical_to_whole_group_decode() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let reader = PocketReader::from_bytes(pocket.to_bytes()).unwrap();
    let rt = session.runtime();
    let cfg = session.manifest().lm_cfg("tiny").unwrap().clone();
    let whole = reader.decode_group(rt, "q").unwrap();
    let gi = &cfg.groups["q"];
    for block in 0..cfg.n_layers {
        let name = format!("b{block}.wq");
        let (chunk, range) = reader.tensor_chunk(rt, &name).unwrap();
        let expect =
            &whole.data[block * gi.rows_per_block * gi.width..(block + 1) * gi.rows_per_block * gi.width];
        assert_eq!(&chunk.data[range.clone()], expect, "{name}");
        // and agrees with the copying tensor() resolution
        assert_eq!(&chunk.data[range], reader.tensor(rt, &name).unwrap().as_slice(), "{name}");
    }
    let st = reader.stats();
    assert_eq!(st.chunk_decodes, cfg.n_layers as u64, "one chunk decode per block");
    assert_eq!(st.chunk_hits, 0);
    // re-accessing a block is a cache hit, not a decode
    let _ = reader.tensor_chunk(rt, "b0.wq").unwrap();
    let st = reader.stats();
    assert_eq!((st.chunk_decodes, st.chunk_hits), (cfg.n_layers as u64, 1));
    // dense tensors resolve through the same surface
    let (emb, r) = reader.tensor_chunk(rt, "embed").unwrap();
    assert_eq!(emb.data[r].len(), cfg.layout.find("embed").unwrap().size);
    // unknown names and bad ranges stay typed
    let e = reader.tensor_chunk(rt, "b0.nope").unwrap_err();
    assert!(matches!(e, pocketllm::Error::UnknownConfig { kind: "tensor", .. }), "{e:?}");
    let e = reader.decode_group_rows(rt, "q", 0, 1_000_000).unwrap_err();
    assert!(matches!(e, pocketllm::Error::ShapeMismatch { .. }), "{e:?}");
    let e = reader.decode_group_rows(rt, "nope", 0, 64).unwrap_err();
    assert!(matches!(e, pocketllm::Error::UnknownGroup { .. }), "{e:?}");
}

#[test]
fn batched_gen_steps_are_bit_identical_to_single_lane() {
    let session = Session::reference();
    let cfg = session.manifest().lm_cfg("tiny").unwrap().clone();
    let ws = WeightStore::init(&cfg, &mut Pcg32::seeded(17));
    let provider = InMemoryProvider::new(&ws);

    // solo references: each stream advanced alone through gen_step
    let solo = |tokens: &[i32]| -> Vec<Vec<f32>> {
        let mut st = GenState::new(&cfg);
        tokens.iter().map(|&t| gen_step(&provider, &mut st, t, |_| {}).unwrap()).collect()
    };
    let t0 = [3i32, 1, 4, 1, 5];
    let t1 = [9i32, 2, 6];
    let s0 = solo(&t0);
    let s1 = solo(&t1);

    // batched: lane 0 runs alone for two steps, then lane 1 joins the
    // half-full batch mid-flight at position 0 while lane 0 is at 2
    let mut st0 = GenState::new(&cfg);
    let mut st1 = GenState::new(&cfg);
    let mut got0 = Vec::new();
    let mut got1 = Vec::new();
    for &t in &t0[..2] {
        let rows = gen_step_batch(&provider, &mut [&mut st0], &[t], |_| {}).unwrap();
        got0.extend(rows);
    }
    let mut hooked = Vec::new();
    for i in 0..3 {
        let rows = gen_step_batch(
            &provider,
            &mut [&mut st0, &mut st1],
            &[t0[2 + i], t1[i]],
            |b| hooked.push(b),
        )
        .unwrap();
        let mut it = rows.into_iter();
        got0.push(it.next().unwrap());
        got1.push(it.next().unwrap());
    }
    assert_eq!(got0, s0, "lane 0 diverged from its solo stream");
    assert_eq!(got1, s1, "lane 1 diverged from its solo stream");
    assert_eq!(st0.pos(), t0.len());
    assert_eq!(st1.pos(), t1.len());
    // one hook per block per batched call, not per lane
    assert_eq!(hooked.len(), 3 * cfg.n_layers);

    // a bad lane fails the whole call before any lane advances
    let pos_before = (st0.pos(), st1.pos());
    let e = gen_step_batch(&provider, &mut [&mut st0, &mut st1], &[0, -1], |_| {}).unwrap_err();
    assert!(format!("{e:#}").contains("lane 1"), "{e:#}");
    assert_eq!((st0.pos(), st1.pos()), pos_before, "failed batch must not advance");
    let e = gen_step_batch(&provider, &mut [&mut st0], &[1, 2], |_| {}).unwrap_err();
    assert!(format!("{e:#}").contains("mismatch"), "{e:#}");
}

#[test]
fn lora_provider_is_bit_identical_to_merged_dense_weights() {
    let session = Session::reference();
    let cfg = session.manifest().lm_cfg("tiny").unwrap().clone();
    let ws = WeightStore::init(&cfg, &mut Pcg32::seeded(29));
    // a synthetic nonzero adapter — a fresh init_lora zeroes the B
    // matrices, which would make the equivalence vacuous
    let lora: Vec<f32> = (0..cfg.lora_layout.total)
        .map(|i| ((i * 37 + 11) % 97) as f32 / 970.0 - 0.05)
        .collect();
    let merged = session.lora_merge(&ws, &lora).unwrap();
    assert_ne!(merged.flat, ws.flat, "the adapter must actually perturb the model");

    let prompt = vec![5i32, 1, 30, 2];
    let mem_merged = session.memory_provider(&merged);
    let baseline = session
        .generate(&mem_merged)
        .prompt(prompt.clone())
        .max_new(6)
        .logits_trace(true)
        .run()
        .unwrap();
    // the lazy per-tensor path: base weights stay unmerged, the adapter
    // folds in at the provider seam with the same op order
    let lp = session.lora_provider(session.memory_provider(&ws), lora.clone()).unwrap();
    let via_lora = session
        .generate(&lp)
        .prompt(prompt.clone())
        .max_new(6)
        .logits_trace(true)
        .run()
        .unwrap();
    assert_eq!(via_lora.tokens, baseline.tokens, "token streams diverged");
    assert_eq!(via_lora.logits_trace, baseline.logits_trace, "adapted logits diverged");

    // sampling rides the same seam deterministically
    let sample = |p: &dyn WeightProvider| {
        session
            .generate(p)
            .prompt(prompt.clone())
            .max_new(6)
            .temperature(0.9)
            .top_k(4)
            .seed(7)
            .run()
            .unwrap()
    };
    let (a, b) = (sample(&mem_merged), sample(&lp));
    assert_eq!(a.tokens, b.tokens, "sampled streams diverged");

    // a mis-sized adapter fails typed at construction
    let e = session.lora_provider(session.memory_provider(&ws), vec![0.0; 3]).unwrap_err();
    assert!(matches!(e, pocketllm::Error::ShapeMismatch { .. }), "{e:?}");
}

#[test]
fn provider_perplexity_matches_backend_eval() {
    let session = Session::reference();
    let cfg = session.manifest().lm_cfg("tiny").unwrap().clone();
    let ws = WeightStore::init(&cfg, &mut Pcg32::seeded(21));
    let corpus = pocketllm::data::Corpus::new(cfg.vocab, 1001);
    let a = eval::perplexity(session.runtime(), &ws, &corpus, 2).unwrap();
    let p = session.memory_provider(&ws);
    let b = eval::perplexity_provider(&p, &corpus, 2).unwrap();
    // the backend path rounds its per-batch totals through f32; otherwise
    // the math is identical
    assert!((a - b).abs() < 1e-4 * a.max(1.0), "{a} vs {b}");
}

#[test]
fn serve_layer_handles_generate_requests() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let reader = Arc::new(PocketReader::from_bytes(pocket.to_bytes()).unwrap());
    let requests = vec![
        ServeRequest::Generate { prompt: vec![1, 2], max_new: 3 },
        ServeRequest::Generate { prompt: vec![9], max_new: 2 },
        ServeRequest::Tensor("b0.wq".to_string()),
    ];
    let report = session.serve(reader.clone()).workers(2).run(&requests).unwrap();
    assert_eq!(report.requests, 3);
    let st = reader.stats();
    assert!(st.chunk_decodes > 0, "generation must ride the chunk path: {st:?}");

    // a bad generation request surfaces as a typed error, not a hang
    let err = session
        .serve(reader)
        .workers(1)
        .run(&[ServeRequest::Generate { prompt: vec![], max_new: 1 }])
        .unwrap_err();
    assert!(matches!(err, pocketllm::Error::ShapeMismatch { .. }), "{err:?}");
}
