//! End-to-end integration tests: train a few steps, compress groups,
//! round-trip the pocket file, verify the device decode path reproduces the
//! coordinator's reconstruction, and check that compression damage behaves
//! monotonically with rate.
//!
//! These run hermetically on the pure-Rust reference backend
//! (`Runtime::reference()`), so `cargo test -q` passes on a clean checkout
//! with no Python step and no AOT artifacts.  `#[ignore]`d PJRT variants at
//! the bottom re-run the core pipeline against the compiled artifacts on
//! machines that built them (`make artifacts` + real xla crate).

use pocketllm::coordinator::job::{compress_group, decode_group, CodebookInit, JobOpts};
use pocketllm::coordinator::{compress_model, lm, reconstruct_from_pocket, PipelineOpts};
use pocketllm::data::tasks::{generate, ZERO_SHOT_SUITES};
use pocketllm::data::Corpus;
use pocketllm::eval::{perplexity, score_instances, zero_shot_accuracy};
use pocketllm::model::{group_rows, WeightStore};
use pocketllm::packfmt::PocketFile;
use pocketllm::runtime::Runtime;
use pocketllm::util::prng::Pcg32;

fn quick_job() -> JobOpts {
    JobOpts {
        train_steps: 60,
        kmeans_iters: 1,
        post_steps: 10,
        codebook_init: CodebookInit::LatentMatched,
        seed: 1,
        log_every: 20,
    }
}

fn full_pipeline_roundtrip_on(rt: &Runtime) {
    let corpus = Corpus::new(512, 77);

    // 1. a few LM steps so weights are non-degenerate
    let (ws, losses) = lm::train_lm(rt, "tiny", &corpus, 8, 3, 0).unwrap();
    assert!(losses.last().unwrap() < losses.first().unwrap());

    // 2. compress two groups at p16x with a quick job
    let opts = PipelineOpts {
        preset: "p16x".into(),
        groups: Some(vec!["q".into(), "up".into()]),
        job: quick_job(),
        ..Default::default()
    };
    let res = compress_model(rt, &ws, &opts).unwrap();
    assert_eq!(res.report.per_group.len(), 2);
    assert!(res.report.avg_bits > 1.0 && res.report.avg_bits < 3.0, "{}", res.report.avg_bits);
    for (g, m) in &res.report.per_group {
        assert!(m.mse_loss.is_finite() && m.mse_loss > 0.0, "{g}");
        assert!(m.codebook_utilization > 0.05, "{g}: {}", m.codebook_utilization);
    }

    // 3. pocket file round-trip through bytes
    let bytes = res.pocket.to_bytes();
    let pocket2 = PocketFile::from_bytes(&bytes).unwrap();

    // 4. device-side reconstruction matches the coordinator's (up to the f16
    //    codebook + scales quantization in the file)
    let ws2 = reconstruct_from_pocket(rt, &pocket2).unwrap();
    let a = group_rows(&res.reconstructed, "q").unwrap();
    let b = group_rows(&ws2, "q").unwrap();
    let mse = a.mse(&b);
    assert!(mse < 1e-5, "decode path diverged: {mse}");
    // untouched groups are bit-identical
    let ka = group_rows(&ws, "k").unwrap();
    let kb = group_rows(&ws2, "k").unwrap();
    assert_eq!(ka.data, kb.data);

    // 5. the compressed model still runs and its ppl is sane
    let ppl_base = perplexity(rt, &ws, &corpus, 2).unwrap();
    let ppl_comp = perplexity(rt, &ws2, &corpus, 2).unwrap();
    assert!(ppl_base.is_finite() && ppl_comp.is_finite());
    assert!(ppl_comp < 520.0, "compressed model saturated: {ppl_comp}");
}

#[test]
fn full_pipeline_roundtrip() {
    full_pipeline_roundtrip_on(&Runtime::reference());
}

#[test]
fn decode_group_matches_assign_reconstruction() {
    let rt = Runtime::reference();
    let mc = rt.manifest.meta_cfg("w256_d8_k512_m3_rln").unwrap().clone();
    let mut rng = Pcg32::seeded(5);
    let mut data = vec![0.0f32; 128 * 256];
    rng.fill_normal(&mut data, 0.04);
    let rows = pocketllm::tensor::TensorF32::new(vec![128, 256], data);
    let res = compress_group(&rt, &mc, &rows, &quick_job()).unwrap();
    let rec = decode_group(
        &rt, &mc,
        &pocketllm::coordinator::job::decoder_slice(&mc, &res.theta),
        &res.codebook, &res.indices, &res.row_scales, 128,
    )
    .unwrap();
    let mse = rec.mse(&res.recon);
    assert!(mse < 1e-10, "decode != assign recon: {mse}");
}

#[test]
fn more_rate_less_damage() {
    // p8x must reconstruct better than p20x on the same rows (Table 1's
    // vertical axis).
    let rt = Runtime::reference();
    let corpus = Corpus::new(512, 88);
    let (ws, _) = lm::train_lm(&rt, "tiny", &corpus, 6, 4, 0).unwrap();
    let rows = group_rows(&ws, "v").unwrap();
    let mut mses = Vec::new();
    for preset in ["p8x", "p20x"] {
        let mc = rt.manifest.meta_for_preset(256, preset).unwrap().clone();
        let res = compress_group(&rt, &mc, &rows, &quick_job()).unwrap();
        mses.push(res.metrics.mse_loss);
    }
    assert!(
        mses[0] < mses[1],
        "8x ({}) should beat 20x ({})",
        mses[0],
        mses[1]
    );
}

#[test]
fn zero_shot_scoring_is_consistent() {
    let rt = Runtime::reference();
    let corpus = Corpus::new(512, 55);
    let cfg = rt.manifest.lm_cfg("tiny").unwrap().clone();
    let ws = WeightStore::init(&cfg, &mut Pcg32::seeded(2));
    // random model ~ chance accuracy on a 2-choice suite
    let acc = zero_shot_accuracy(&rt, &ws, &corpus, &ZERO_SHOT_SUITES[0], 60, 3).unwrap();
    assert!((0.2..=0.8).contains(&acc), "untrained acc {acc}");
    // scores have the right arity
    let insts = generate(&corpus, &ZERO_SHOT_SUITES[2], 5, 4);
    let scores = score_instances(&rt, &ws, &insts).unwrap();
    assert_eq!(scores.len(), 5);
    assert!(scores.iter().all(|s| s.len() == 4));
    assert!(scores.iter().flatten().all(|v| v.is_finite()));
}

#[test]
fn lora_finetune_improves_compressed_model() {
    let rt = Runtime::reference();
    let corpus = Corpus::new(512, 66);
    let (ws, _) = lm::train_lm(&rt, "tiny", &corpus, 12, 5, 0).unwrap();
    // damage the model hard (p20x on three groups, tiny budget)
    let opts = PipelineOpts {
        preset: "p20x".into(),
        groups: Some(vec!["q".into(), "v".into(), "up".into()]),
        job: JobOpts { train_steps: 25, kmeans_iters: 0, post_steps: 0, ..quick_job() },
        ..Default::default()
    };
    let res = compress_model(&rt, &ws, &opts).unwrap();
    let ppl_damaged = perplexity(&rt, &res.reconstructed, &corpus, 2).unwrap();
    let recovered = lm::lora_finetune(&rt, &res.reconstructed, &corpus, 15, 6).unwrap();
    let ppl_rec = perplexity(&rt, &recovered, &corpus, 2).unwrap();
    assert!(
        ppl_rec < ppl_damaged,
        "LoRA did not help: {ppl_damaged} -> {ppl_rec}"
    );
}

/// The compress path is deterministic on the reference backend even though
/// groups fan out over worker threads: same seed, same pocket bytes.
#[test]
fn parallel_compress_is_deterministic() {
    let rt = Runtime::reference();
    let cfg = rt.manifest.lm_cfg("tiny").unwrap().clone();
    let ws = WeightStore::init(&cfg, &mut Pcg32::seeded(21));
    let opts = PipelineOpts {
        preset: "p20x".into(),
        groups: Some(vec!["q".into(), "k".into(), "v".into()]),
        job: JobOpts { train_steps: 12, kmeans_iters: 1, post_steps: 4, ..quick_job() },
        ..Default::default()
    };
    let a = compress_model(&rt, &ws, &opts).unwrap();
    let b = compress_model(&rt, &ws, &opts).unwrap();
    assert_eq!(a.pocket.to_bytes(), b.pocket.to_bytes());
    assert_eq!(a.reconstructed.flat, b.reconstructed.flat);
}

#[test]
#[ignore = "needs artifacts + real xla crate: run on a machine after `make artifacts`"]
fn full_pipeline_roundtrip_pjrt() {
    let rt = Runtime::pjrt(&Runtime::default_artifacts_dir()).expect("artifacts built");
    full_pipeline_roundtrip_on(&rt);
}

#[test]
#[ignore = "needs artifacts + real xla crate: run on a machine after `make artifacts`"]
fn decode_group_matches_assign_reconstruction_pjrt() {
    let rt = Runtime::pjrt(&Runtime::default_artifacts_dir()).expect("artifacts built");
    let mc = rt.manifest.meta_cfg("w256_d8_k512_m3_rln").unwrap().clone();
    let mut rng = Pcg32::seeded(5);
    let mut data = vec![0.0f32; 128 * 256];
    rng.fill_normal(&mut data, 0.04);
    let rows = pocketllm::tensor::TensorF32::new(vec![128, 256], data);
    let res = compress_group(&rt, &mc, &rows, &quick_job()).unwrap();
    let rec = decode_group(
        &rt, &mc,
        &pocketllm::coordinator::job::decoder_slice(&mc, &res.theta),
        &res.codebook, &res.indices, &res.row_scales, 128,
    )
    .unwrap();
    let mse = rec.mse(&res.recon);
    assert!(mse < 1e-10, "decode != assign recon: {mse}");
}
