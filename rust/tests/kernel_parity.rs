//! Golden-vector parity: small fixed-seed inputs through each reference
//! kernel, asserted against checked-in outputs of the pure-jnp oracles in
//! `python/compile/kernels/ref.py` (tolerance 1e-5).
//!
//! The golden file is generated once by `python/tests/gen_golden.py` and
//! committed, so this suite needs no Python at test time.  If ref.py ever
//! changes semantics, regenerate with `cd python && python -m tests.gen_golden`.

use std::path::Path;

use pocketllm::runtime::reference::ops;
use pocketllm::util::json::Json;

const TOL: f32 = 1e-5;

fn golden() -> Json {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/kernels.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {path:?}: {e} (run python -m tests.gen_golden)"));
    Json::parse(&text).expect("parsing golden kernels.json")
}

fn floats(j: &Json) -> Vec<f32> {
    j.as_arr()
        .expect("float array")
        .iter()
        .map(|v| v.as_f64().expect("float") as f32)
        .collect()
}

fn ints(j: &Json) -> Vec<i32> {
    j.as_arr()
        .expect("int array")
        .iter()
        .map(|v| v.as_i64().expect("int") as i32)
        .collect()
}

fn assert_close(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= TOL,
            "{what}: index {i}: got {g}, want {w} (tol {TOL})"
        );
    }
}

#[test]
fn rln_matches_ref() {
    let g = golden();
    for (ci, case) in g.get("rln").unwrap().as_arr().unwrap().iter().enumerate() {
        let r = case.get("R").unwrap().as_usize().unwrap();
        let w = case.get("W").unwrap().as_usize().unwrap();
        let x = floats(case.get("x").unwrap());
        let want = floats(case.get("y").unwrap());
        let got = ops::rln(&x, r, w);
        assert_close(&got, &want, &format!("rln case {ci}"));
    }
}

#[test]
fn ln_matches_ref() {
    let g = golden();
    for (ci, case) in g.get("ln").unwrap().as_arr().unwrap().iter().enumerate() {
        let r = case.get("R").unwrap().as_usize().unwrap();
        let w = case.get("W").unwrap().as_usize().unwrap();
        let d = case.get("d").unwrap().as_usize().unwrap();
        let x = floats(case.get("x").unwrap());
        let want = floats(case.get("y").unwrap());
        let got = ops::ln(&x, r, w, d);
        assert_close(&got, &want, &format!("ln case {ci}"));
    }
}

#[test]
fn mlp_block_matches_ref() {
    let g = golden();
    for (ci, case) in g.get("mlp_block").unwrap().as_arr().unwrap().iter().enumerate() {
        let r = case.get("R").unwrap().as_usize().unwrap();
        let w = case.get("W").unwrap().as_usize().unwrap();
        let din = case.get("din").unwrap().as_usize().unwrap();
        let dout = case.get("dout").unwrap().as_usize().unwrap();
        let norm = case.get("norm").unwrap().as_str().unwrap();
        let residual = matches!(case.get("residual").unwrap(), Json::Bool(true));
        let activate = matches!(case.get("activate").unwrap(), Json::Bool(true));
        let x = floats(case.get("x").unwrap());
        let wm = floats(case.get("w").unwrap());
        let b = floats(case.get("b").unwrap());
        let want = floats(case.get("y").unwrap());
        let got = ops::mlp_block(&x, r, w, &wm, &b, din, dout, norm, residual, activate);
        assert_close(&got, &want, &format!("mlp_block case {ci} ({norm})"));
    }
}

#[test]
fn vq_assign_matches_ref() {
    let g = golden();
    for (ci, case) in g.get("vq_assign").unwrap().as_arr().unwrap().iter().enumerate() {
        let n = case.get("N").unwrap().as_usize().unwrap();
        let d = case.get("d").unwrap().as_usize().unwrap();
        let k = case.get("K").unwrap().as_usize().unwrap();
        let z = floats(case.get("z").unwrap());
        let c = floats(case.get("c").unwrap());
        let want_idx = ints(case.get("idx").unwrap());
        let want_sq = floats(case.get("sq").unwrap());
        let (idx, sq) = ops::vq_assign(&z, n, d, &c, k);
        assert_eq!(idx, want_idx, "vq_assign case {ci}: indices");
        assert_close(&sq, &want_sq, &format!("vq_assign case {ci} sqdist"));
    }
}

#[test]
fn gather_rows_matches_ref() {
    let g = golden();
    for (ci, case) in g.get("gather_rows").unwrap().as_arr().unwrap().iter().enumerate() {
        let d = case.get("d").unwrap().as_usize().unwrap();
        let c = floats(case.get("c").unwrap());
        let idx = ints(case.get("idx").unwrap());
        let want = floats(case.get("y").unwrap());
        let got = ops::gather(&c, d, &idx);
        assert_close(&got, &want, &format!("gather_rows case {ci}"));
    }
}

/// The golden file covers every kernel family ref.py exports.
#[test]
fn golden_file_is_complete() {
    let g = golden();
    for key in ["rln", "ln", "mlp_block", "vq_assign", "gather_rows"] {
        let cases = g.get(key).unwrap().as_arr().unwrap();
        assert!(!cases.is_empty(), "{key}: no golden cases");
    }
}
