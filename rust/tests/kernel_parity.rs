//! Golden-vector parity: small fixed-seed inputs through each reference
//! kernel, asserted against checked-in outputs of the pure-jnp oracles in
//! `python/compile/kernels/ref.py` (tolerance 1e-5).
//!
//! The golden file is generated once by `python/tests/gen_golden.py` and
//! committed, so this suite needs no Python at test time.  If ref.py ever
//! changes semantics, regenerate with `cd python && python -m tests.gen_golden`.

use std::path::Path;

use pocketllm::runtime::reference::ops;
use pocketllm::util::json::Json;

const TOL: f32 = 1e-5;

fn golden() -> Json {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/kernels.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {path:?}: {e} (run python -m tests.gen_golden)"));
    Json::parse(&text).expect("parsing golden kernels.json")
}

fn floats(j: &Json) -> Vec<f32> {
    j.as_arr()
        .expect("float array")
        .iter()
        .map(|v| v.as_f64().expect("float") as f32)
        .collect()
}

fn ints(j: &Json) -> Vec<i32> {
    j.as_arr()
        .expect("int array")
        .iter()
        .map(|v| v.as_i64().expect("int") as i32)
        .collect()
}

fn assert_close(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= TOL,
            "{what}: index {i}: got {g}, want {w} (tol {TOL})"
        );
    }
}

#[test]
fn rln_matches_ref() {
    let g = golden();
    for (ci, case) in g.get("rln").unwrap().as_arr().unwrap().iter().enumerate() {
        let r = case.get("R").unwrap().as_usize().unwrap();
        let w = case.get("W").unwrap().as_usize().unwrap();
        let x = floats(case.get("x").unwrap());
        let want = floats(case.get("y").unwrap());
        let got = ops::rln(&x, r, w);
        assert_close(&got, &want, &format!("rln case {ci}"));
    }
}

#[test]
fn ln_matches_ref() {
    let g = golden();
    for (ci, case) in g.get("ln").unwrap().as_arr().unwrap().iter().enumerate() {
        let r = case.get("R").unwrap().as_usize().unwrap();
        let w = case.get("W").unwrap().as_usize().unwrap();
        let d = case.get("d").unwrap().as_usize().unwrap();
        let x = floats(case.get("x").unwrap());
        let want = floats(case.get("y").unwrap());
        let got = ops::ln(&x, r, w, d);
        assert_close(&got, &want, &format!("ln case {ci}"));
    }
}

#[test]
fn mlp_block_matches_ref() {
    let g = golden();
    for (ci, case) in g.get("mlp_block").unwrap().as_arr().unwrap().iter().enumerate() {
        let r = case.get("R").unwrap().as_usize().unwrap();
        let w = case.get("W").unwrap().as_usize().unwrap();
        let din = case.get("din").unwrap().as_usize().unwrap();
        let dout = case.get("dout").unwrap().as_usize().unwrap();
        let norm = case.get("norm").unwrap().as_str().unwrap();
        let residual = matches!(case.get("residual").unwrap(), Json::Bool(true));
        let activate = matches!(case.get("activate").unwrap(), Json::Bool(true));
        let x = floats(case.get("x").unwrap());
        let wm = floats(case.get("w").unwrap());
        let b = floats(case.get("b").unwrap());
        let want = floats(case.get("y").unwrap());
        let got = ops::mlp_block(&x, r, w, &wm, &b, din, dout, norm, residual, activate);
        assert_close(&got, &want, &format!("mlp_block case {ci} ({norm})"));
    }
}

#[test]
fn vq_assign_matches_ref() {
    let g = golden();
    for (ci, case) in g.get("vq_assign").unwrap().as_arr().unwrap().iter().enumerate() {
        let n = case.get("N").unwrap().as_usize().unwrap();
        let d = case.get("d").unwrap().as_usize().unwrap();
        let k = case.get("K").unwrap().as_usize().unwrap();
        let z = floats(case.get("z").unwrap());
        let c = floats(case.get("c").unwrap());
        let want_idx = ints(case.get("idx").unwrap());
        let want_sq = floats(case.get("sq").unwrap());
        let (idx, sq) = ops::vq_assign(&z, n, d, &c, k);
        assert_eq!(idx, want_idx, "vq_assign case {ci}: indices");
        assert_close(&sq, &want_sq, &format!("vq_assign case {ci} sqdist"));
    }
}

#[test]
fn gather_rows_matches_ref() {
    let g = golden();
    for (ci, case) in g.get("gather_rows").unwrap().as_arr().unwrap().iter().enumerate() {
        let d = case.get("d").unwrap().as_usize().unwrap();
        let c = floats(case.get("c").unwrap());
        let idx = ints(case.get("idx").unwrap());
        let want = floats(case.get("y").unwrap());
        let got = ops::gather(&c, d, &idx);
        assert_close(&got, &want, &format!("gather_rows case {ci}"));
    }
}

/// SIMD lanes of the fused microkernels against the scalar lane, bitwise,
/// on fixed vectors — plus hand-computed golden values on dyadic-rational
/// inputs where every intermediate is exactly representable, so the
/// expected output is lane-independent by construction (no Python oracle
/// needed).  Runs every kernel `all_supported()` reports, which includes
/// the vector lane on AVX2/NEON hosts and degrades to scalar-only
/// elsewhere (or under `POCKETLLM_FORCE_SCALAR=1`).
#[test]
fn fused_simd_lanes_match_scalar_and_golden() {
    use pocketllm::Kernel;

    // 37 elements: not a multiple of any lane width, so vector bodies and
    // scalar tails both execute; values include zeros, negative zero and
    // a denormal to pin sign/flush behavior.
    let src: Vec<f32> = (0..37u32)
        .map(|i| match i % 11 {
            3 => 0.0,
            7 => -0.0,
            9 => 1e-40,
            _ => {
                let h = i.wrapping_mul(2654435761);
                (h >> 9) as f32 / (1u32 << 22) as f32 - 1.0
            }
        })
        .collect();
    let base: Vec<f32> = src.iter().rev().cloned().collect();
    let table: Vec<f32> = (0..6 * 37).map(|i| src[i % 37] * 1.5 - 0.25).collect();
    let irow: Vec<u32> = (0..9).map(|i| (i * 5 + 2) % 6).collect();
    let a = 0.8125f32;
    let scalar = Kernel::Scalar;
    for kern in Kernel::all_supported() {
        // exact axpy: mul+add two-rounding semantics are lane-invariant
        let mut want = base.clone();
        scalar.axpy(&mut want, a, &src);
        let mut got = base.clone();
        kern.axpy(&mut got, a, &src);
        assert_eq!(want, got, "axpy: {} diverged from scalar", kern.name());
        // exact gather-axpy over a [6, 37] table
        let mut want = vec![0.0f32; irow.len() * 37];
        scalar.gather_axpy_exact(&mut want, a, -0.125, 0.75, &table, 37, &irow);
        let mut got = vec![0.0f32; irow.len() * 37];
        kern.gather_axpy_exact(&mut got, a, -0.125, 0.75, &table, 37, &irow);
        assert_eq!(want, got, "gather_axpy_exact: {} diverged from scalar", kern.name());
        // f16 accumulator: rounds through half precision identically
        let mut want = base.clone();
        scalar.axpy_f16(&mut want, a, &src);
        let mut got = base.clone();
        kern.axpy_f16(&mut got, a, &src);
        assert_eq!(want, got, "axpy_f16: {} diverged from scalar", kern.name());
        // relaxed fma lane: tolerance, not bit equality
        let mut fma = base.clone();
        kern.axpy_fma(&mut fma, a, &src);
        let mut exact = base.clone();
        scalar.axpy(&mut exact, a, &src);
        for (i, (g, w)) in fma.iter().zip(&exact).enumerate() {
            assert!(
                (g - w).abs() <= 1e-5 * w.abs().max(1.0),
                "axpy_fma: {} index {i}: {g} vs {w}",
                kern.name()
            );
        }

        // golden axpy: dst[i] = 1.0 + 0.75 * b[i], every product dyadic
        let b = [2.0f32, -4.0, 0.5, 8.0, 1.25, -0.25, 16.0, 0.0, -2.5];
        let mut dst = [1.0f32; 9];
        kern.axpy(&mut dst, 0.75, &b);
        let golden = [2.5f32, -2.0, 1.375, 7.0, 1.9375, 0.8125, 13.0, 1.0, -0.875];
        assert_eq!(dst, golden, "axpy golden: {}", kern.name());
        // golden gather-axpy: d=2, k=3 table, out += 2*(t*0.5 + 0.25)
        let t = [1.0f32, -2.0, 0.5, 4.0, -1.5, 0.25];
        let mut out = [0.0f32; 4];
        kern.gather_axpy_exact(&mut out, 2.0, 0.25, 0.5, &t, 2, &[2, 0]);
        assert_eq!(out, [-1.0f32, 0.75, 1.5, -1.5], "gather golden: {}", kern.name());
    }
    // the dispatcher always reports something this host supports
    assert!(Kernel::all_supported().contains(&Kernel::active()));
}

/// The golden file covers every kernel family ref.py exports.
#[test]
fn golden_file_is_complete() {
    let g = golden();
    for key in ["rln", "ln", "mlp_block", "vq_assign", "gather_rows"] {
        let cases = g.get(key).unwrap().as_arr().unwrap();
        assert!(!cases.is_empty(), "{key}: no golden cases");
    }
}
