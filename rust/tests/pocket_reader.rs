//! Integration tests for the Session/PocketReader API redesign:
//!
//! * a POCKET02 file round-trips **bit-identically** through
//!   `PocketReader::reconstruct_all()` vs the historical eager path;
//! * legacy POCKET01 blobs still load (file + reader);
//! * decoding a single group reads only that group's TOC section
//!   (byte/decode counters);
//! * the decoded-group LRU: a second decode is a cache hit, not a backend
//!   call;
//! * truncation / TOC corruption / checksum failures surface as
//!   `Error::Format`.
//!
//! Everything runs hermetically on the pure-Rust reference backend.

use pocketllm::coordinator::{compress_model, lm, reconstruct_from_pocket, PipelineOpts};
use pocketllm::coordinator::job::JobOpts;
use pocketllm::data::Corpus;
use pocketllm::model::group_rows;
use pocketllm::packfmt::{PocketFile, PocketReader};
use pocketllm::session::Session;
use pocketllm::Error;

mod common;
use common::compressed_pocket;

#[test]
fn pocket02_reconstructs_bit_identically_to_eager_path() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);

    // write the POCKET02 container to disk and reopen it lazily
    let path = std::env::temp_dir().join("pocketllm_test_roundtrip.pocket");
    pocket.save(&path).unwrap();
    let loaded = PocketFile::load(&path).unwrap();

    // the historical eager path on the loaded file
    let eager = reconstruct_from_pocket(session.runtime(), &loaded).unwrap();
    // the lazy reader on the same container
    let reader = PocketReader::open(&path).unwrap();
    let lazy = reader.reconstruct_all(session.runtime()).unwrap();
    assert_eq!(eager.flat, lazy.flat, "lazy decode diverged from the eager path");

    // and the in-memory wrapper (no re-encode) matches the direct decode of
    // the in-memory pocket
    let wrapped = PocketReader::from_pocket(pocket.clone())
        .reconstruct_all(session.runtime())
        .unwrap();
    let direct = reconstruct_from_pocket(session.runtime(), &pocket).unwrap();
    assert_eq!(wrapped.flat, direct.flat);
    std::fs::remove_file(&path).ok();
}

#[test]
fn legacy_pocket01_still_loads_and_decodes() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);

    let v1 = pocket.to_bytes_v1();
    let v2 = pocket.to_bytes();
    assert_eq!(&v1[..8], b"POCKET01");
    assert_eq!(&v2[..8], b"POCKET02");

    // PocketFile parses both revisions
    let f1 = PocketFile::from_bytes(&v1).unwrap();
    let f2 = PocketFile::from_bytes(&v2).unwrap();
    assert_eq!(f1.groups.len(), f2.groups.len());
    assert_eq!(f1.dense.len(), f2.dense.len());

    // and both decode to the same weights through the reader
    let w1 = PocketReader::from_bytes(v1).unwrap().reconstruct_all(session.runtime()).unwrap();
    let w2 = PocketReader::from_bytes(v2).unwrap().reconstruct_all(session.runtime()).unwrap();
    assert_eq!(w1.flat, w2.flat, "v1 and v2 containers decoded differently");
}

#[test]
fn single_group_decode_reads_only_that_section() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let bytes = pocket.to_bytes();
    let total = bytes.len() as u64;

    let reader = PocketReader::from_bytes(bytes).unwrap();
    // open touched only the header + TOC
    let s0 = reader.stats();
    assert_eq!(s0.bytes_read, reader.header_bytes());
    assert_eq!((s0.sections_read, s0.group_decodes), (0, 0));

    // decoding "q" pulls exactly the "q" section
    let q = reader.decode_group(session.runtime(), "q").unwrap();
    let s1 = reader.stats();
    assert_eq!(s1.sections_read, 1);
    assert_eq!(
        s1.bytes_read,
        reader.header_bytes() + reader.section_length("q").unwrap(),
        "decode of one group read more than its own section"
    );
    assert!(s1.bytes_read < total, "single-group decode read the whole container");
    assert_eq!(s1.group_decodes, 1);

    // the decoded rows are the real thing, not a stub
    let direct = reconstruct_from_pocket(session.runtime(), &pocket).unwrap();
    let expect = group_rows(&direct, "q").unwrap();
    assert_eq!(q.data, expect.data);
}

#[test]
fn second_decode_is_a_cache_hit_not_a_backend_call() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let reader = PocketReader::from_bytes(pocket.to_bytes()).unwrap();

    let a = reader.decode_group(session.runtime(), "up").unwrap();
    let s1 = reader.stats();
    assert_eq!((s1.group_decodes, s1.cache_hits), (1, 0));

    let b = reader.decode_group(session.runtime(), "up").unwrap();
    let s2 = reader.stats();
    assert_eq!(s2.group_decodes, 1, "second decode hit the backend again");
    assert_eq!(s2.cache_hits, 1);
    assert_eq!(s2.sections_read, s1.sections_read, "cache hit re-read the section");
    assert_eq!(a.data, b.data);
}

#[test]
fn named_tensor_decodes_through_its_group_or_dense_residue() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let reader = PocketReader::from_bytes(pocket.to_bytes()).unwrap();
    let direct = reconstruct_from_pocket(session.runtime(), &pocket).unwrap();

    // a tensor inside a compressed group ("q" was compressed)
    let t = reader.tensor(session.runtime(), "b0.wq").unwrap();
    let e = direct.cfg.layout.find("b0.wq").unwrap();
    assert_eq!(t, direct.flat[e.offset..e.offset + e.size].to_vec());

    // a dense residue tensor ("v" was left dense)
    let t = reader.tensor(session.runtime(), "b0.wv").unwrap();
    let e = direct.cfg.layout.find("b0.wv").unwrap();
    assert_eq!(t, direct.flat[e.offset..e.offset + e.size].to_vec());

    // unknown names are typed errors
    assert!(matches!(
        reader.tensor(session.runtime(), "b9.zzz").unwrap_err(),
        Error::UnknownConfig { .. }
    ));
}

#[test]
fn truncated_and_corrupted_containers_are_format_errors() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let bytes = pocket.to_bytes();

    // truncations at the magic, inside the TOC, and inside a payload
    for cut in [4usize, 12, 40, bytes.len() / 2, bytes.len() - 1] {
        let e = PocketFile::from_bytes(&bytes[..cut]).unwrap_err();
        assert!(matches!(e, Error::Format { .. }), "cut at {cut}: {e:?}");
    }

    // a corrupted payload byte fails its section checksum on access
    let reader0 = PocketReader::from_bytes(bytes.clone()).unwrap();
    let header = reader0.header_bytes() as usize;
    let mut bad = bytes.clone();
    bad[header + 5] ^= 0x10;
    let e = PocketFile::from_bytes(&bad).unwrap_err();
    match &e {
        Error::Format { detail, .. } => assert!(detail.contains("checksum"), "{detail}"),
        other => panic!("expected Format error, got {other:?}"),
    }

    // same through the lazy reader: open succeeds (header is intact),
    // touching the damaged group fails typed
    let reader = PocketReader::from_bytes(bad).unwrap();
    let first = reader.group_names()[0].clone();
    let e = reader.group_record(&first).unwrap_err();
    assert!(matches!(e, Error::Format { .. }), "{e:?}");

    // TOC corruption is rejected at open
    let mut bad_toc = bytes.clone();
    bad_toc[18] = 0xFF; // inside the lm_cfg string length / name region
    assert!(PocketReader::from_bytes(bad_toc).is_err());
}

/// The legacy entry points still compose with the new surface: compress via
/// the free function, decode via the reader, identical bytes.
#[test]
fn free_function_pipeline_interoperates_with_reader() {
    let session = Session::reference();
    let corpus = Corpus::new(512, 99);
    let (ws, _) = lm::train_lm(session.runtime(), "tiny", &corpus, 5, 2, 0).unwrap();
    let opts = PipelineOpts {
        preset: "p20x".into(),
        groups: Some(vec!["v".into()]),
        job: JobOpts { train_steps: 15, kmeans_iters: 0, post_steps: 0, ..Default::default() },
        ..Default::default()
    };
    let res = compress_model(session.runtime(), &ws, &opts).unwrap();
    let eager = reconstruct_from_pocket(session.runtime(), &res.pocket).unwrap();
    let lazy = PocketReader::from_bytes(res.pocket.to_bytes())
        .unwrap()
        .reconstruct_all(session.runtime())
        .unwrap();
    // the serialized container rounds the codebook/scales to f16, so compare
    // against the eager path on the *serialized* file, which does the same
    let eager_serialized = reconstruct_from_pocket(
        session.runtime(),
        &PocketFile::from_bytes(&res.pocket.to_bytes()).unwrap(),
    )
    .unwrap();
    assert_eq!(lazy.flat, eager_serialized.flat);
    // and the in-memory eager path agrees up to that f16 rounding
    let mse: f64 = eager
        .flat
        .iter()
        .zip(&lazy.flat)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / eager.flat.len() as f64;
    assert!(mse < 1e-5, "f16 container rounding too large: {mse}");
}
