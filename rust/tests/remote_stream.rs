//! Integration tests for the remote streaming path — `HttpSource` against
//! the in-process loopback range server (`util::testserver::RangeServer`),
//! fully offline:
//!
//! * a remote open reads only the header + TOC, and a full decode through
//!   `HttpSource` is **bit-identical** to the in-memory (`MemSource`) path;
//! * the TOC-guided `PrefetchPlan` coalesces adjacent sections: a full
//!   decode issues exactly **one fetch per coalesced window** — strictly
//!   fewer round trips than per-section reads — and a warm decode touches
//!   the wire not at all;
//! * retry-with-backoff recovers from every scripted fault class (drop
//!   before response, drop mid-body, stall past the read timeout, 5xx,
//!   short body) and the recovered bytes are still bit-identical;
//! * out-of-bounds reads fail locally (no wire traffic), a `416` is a
//!   permanent fail-fast error, and the server's own 416 framing is
//!   correct on the wire;
//! * a property over arbitrary coalescing policies, window-cache sizes and
//!   eventually-successful fault schedules: reconstruction through
//!   `HttpSource` always matches the eager decode bit for bit.
//!
//! Everything runs on the pure-Rust reference backend over 127.0.0.1.

use std::io;
use std::io::{Read, Write};
use std::time::Duration;

use pocketllm::packfmt::{HttpOptions, HttpSource, PocketReader, RetryPolicy};
use pocketllm::session::Session;
use pocketllm::util::quickcheck::{prop_assert, property_cases};
use pocketllm::util::testserver::{Fault, RangeServer};
use pocketllm::SectionSource;

mod common;
use common::compressed_pocket;

/// Fast-retry client options so fault tests don't sleep through CI.
fn fast_opts() -> HttpOptions {
    HttpOptions {
        timeout: Duration::from_millis(200),
        retry: RetryPolicy { attempts: 5, backoff: Duration::from_millis(2) },
        max_windows: 16,
    }
}

#[test]
fn http_decode_is_bit_identical_to_mem_and_open_stays_lazy() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let bytes = pocket.to_bytes();
    let total = bytes.len() as u64;
    let server = RangeServer::serve(bytes.clone()).unwrap();
    assert!(server.addr().ip().is_loopback(), "harness must stay on loopback");

    let remote = PocketReader::open_url(&server.url()).unwrap();
    let s0 = remote.stats();
    assert_eq!(s0.bytes_read, remote.header_bytes());
    let at_open = s0.source.expect("http transport must report fetch stats");
    assert!(at_open.bytes_fetched < total, "open must not download the container");
    assert_eq!(at_open.retries, 0);

    let mem = PocketReader::from_bytes(bytes).unwrap();
    let a = remote.reconstruct_all(session.runtime()).unwrap();
    let b = mem.reconstruct_all(session.runtime()).unwrap();
    assert_eq!(a.flat, b.flat, "remote decode diverged from the in-memory path");

    // every byte travelled as loopback HTTP the server saw and logged
    assert!(server.request_count() > 0);
    assert!(server.requests().iter().all(|r| r.method == "HEAD" || r.method == "GET"));
    assert!(server.requests().iter().all(|r| r.fault.is_none()));
}

#[test]
fn coalesced_windows_fetch_once_and_beat_per_section_reads() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let server = RangeServer::serve(pocket.to_bytes()).unwrap();

    let src = HttpSource::connect(&server.url()).unwrap();
    let handle = src.clone();
    let reader = PocketReader::open_http(src).unwrap();
    let plan = handle.plan();
    let mut names = reader.group_names();
    names.extend(reader.dense_names());
    assert!(!plan.is_empty(), "open_http must install a TOC-guided plan");
    assert!(plan.len() < names.len(), "adjacent sections must coalesce");

    let after_open = handle.range_log().len();
    reader.reconstruct_all(session.runtime()).unwrap();
    let log = handle.range_log();
    let fetched = &log[after_open..];
    assert_eq!(
        fetched.len(),
        plan.len(),
        "expected exactly one fetch per coalesced window, got {fetched:?}"
    );
    for r in fetched {
        assert!(plan.windows().contains(r), "fetch {r:?} is not a whole planned window");
    }
    // the coalescing claim: strictly fewer round trips than sections
    assert!(fetched.len() < names.len(), "windows did not beat per-section reads");

    // a second full decode rides the decode cache: zero new wire traffic
    let before = server.request_count();
    reader.reconstruct_all(session.runtime()).unwrap();
    assert_eq!(server.request_count(), before, "warm reconstruct touched the wire");
    // ... which also covers the dense residue: no per-request re-reads
    let st = reader.stats();
    assert_eq!(st.dense_sections_read, reader.dense_names().len() as u64);
    assert!(st.dense_hits >= reader.dense_names().len() as u64);
}

#[test]
fn retry_recovers_from_every_scripted_fault_class() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let bytes = pocket.to_bytes();
    let expect = PocketReader::from_bytes(bytes.clone())
        .unwrap()
        .reconstruct_all(session.runtime())
        .unwrap();

    let faults = [
        ("close-before-response", Fault::CloseBeforeResponse),
        ("drop-after", Fault::DropAfter(7)),
        ("stall", Fault::Stall(Duration::from_millis(500))),
        ("status-500", Fault::Status(500)),
        ("short-body", Fault::ShortBody(3)),
    ];
    for (name, fault) in faults {
        let server = RangeServer::serve(bytes.clone()).unwrap();
        let src = HttpSource::connect_with(&server.url(), fast_opts()).unwrap();
        let handle = src.clone();
        let reader = PocketReader::open_http(src).unwrap();

        server.push_fault(fault);
        let ws = reader
            .reconstruct_all(session.runtime())
            .unwrap_or_else(|e| panic!("fault {name}: decode failed to recover: {e}"));
        assert_eq!(ws.flat, expect.flat, "fault {name}: recovered decode diverged");
        assert!(handle.retries() >= 1, "fault {name}: recovery happened without a retry");
        assert_eq!(server.pending_faults(), 0, "fault {name}: fault never fired");
        let log = server.requests();
        assert!(log.iter().any(|r| r.fault.is_some()), "fault {name}: not logged");
    }
}

#[test]
fn out_of_bounds_reads_fail_locally_and_416_fails_fast() {
    let body: Vec<u8> = (0u8..200).collect();
    let server = RangeServer::serve(body).unwrap();
    let src = HttpSource::connect(&server.url()).unwrap();
    assert_eq!(src.len(), 200);
    let after_connect = server.request_count();

    // the client bounds-checks before the wire: an overrun read is a local
    // typed EOF and produces zero traffic
    let mut buf = [0u8; 16];
    let e = src.read_at(192, &mut buf).unwrap_err();
    assert_eq!(e.kind(), io::ErrorKind::UnexpectedEof);
    let e = src.read_at(u64::MAX, &mut buf).unwrap_err();
    assert_eq!(e.kind(), io::ErrorKind::UnexpectedEof, "offset overflow must not wrap");
    assert_eq!(server.request_count(), after_connect, "overrun read reached the wire");

    // a scripted 416 (a mirror serving a shorter container than its HEAD
    // promised) is permanent: exactly one request, no retries
    server.push_fault(Fault::Status(416));
    let e = src.read_at(0, &mut buf).unwrap_err();
    assert_eq!(e.kind(), io::ErrorKind::InvalidInput, "4xx must be permanent: {e}");
    assert_eq!(server.request_count(), after_connect + 1, "a 4xx response was retried");
    assert_eq!(src.retries(), 0);

    // the source recovers on the next (reconnected) request
    src.read_at(10, &mut buf).unwrap();
    assert_eq!(&buf[..4], &[10, 11, 12, 13]);

    // and on the wire, a genuinely unsatisfiable range gets the full 416
    // framing (status + `Content-Range: bytes */total`)
    let mut s = std::net::TcpStream::connect(server.addr()).unwrap();
    s.write_all(b"GET /pocket HTTP/1.1\r\nHost: x\r\nRange: bytes=900-950\r\n\r\n").unwrap();
    s.shutdown(std::net::Shutdown::Write).ok();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 416"), "{resp}");
    assert!(resp.contains("Content-Range: bytes */200"), "{resp}");
}

#[test]
fn property_http_reconstruction_is_bit_identical_under_faults() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let bytes = pocket.to_bytes();
    let expect = PocketReader::from_bytes(bytes.clone())
        .unwrap()
        .reconstruct_all(session.runtime())
        .unwrap();

    property_cases("http streaming reconstruction", 8, |g| {
        let server = RangeServer::serve(bytes.clone()).map_err(|e| e.to_string())?;
        let opts = HttpOptions {
            timeout: Duration::from_millis(200),
            retry: RetryPolicy { attempts: 5, backoff: Duration::from_millis(1) },
            // arbitrary window-cache pressure, down to a single window
            max_windows: g.usize_in(1, 8),
        };
        let src = HttpSource::connect_with(&server.url(), opts).map_err(|e| e.to_string())?;
        let handle = src.clone();
        let reader = PocketReader::open_http(src).map_err(|e| e.to_string())?;
        // arbitrary coalescing policy, from per-section to everything-merges
        let max_gap = g.u64_in(0, 8192);
        let max_window = g.u64_in(64, 1 << 22);
        handle.install_plan(reader.prefetch_plan(max_gap, max_window));

        // a fault schedule that eventually succeeds: at most two queued
        // faults, each absorbed by the 5-attempt retry budget
        for _ in 0..g.usize_in(0, 2) {
            let fault = match g.int_in(0, 3) {
                0 => Fault::CloseBeforeResponse,
                1 => Fault::DropAfter(g.usize_in(0, 64)),
                2 => Fault::Status(503),
                _ => Fault::ShortBody(g.usize_in(1, 32)),
            };
            server.push_fault(fault);
        }

        let ws = reader
            .reconstruct_all(session.runtime())
            .map_err(|e| format!("decode failed under faults: {e}"))?;
        prop_assert(ws.flat == expect.flat, "remote reconstruction diverged")
    });
}

#[test]
fn headless_mirror_opens_via_range_probe_and_decodes_identically() {
    // a GET-only mirror: every HEAD is 405, so the client must learn the
    // container length from a one-byte range probe's Content-Range total
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let bytes = pocket.to_bytes();
    let total = bytes.len() as u64;
    let server = RangeServer::serve(bytes.clone()).unwrap();
    server.disable_head();

    let remote = PocketReader::open_url(&server.url()).unwrap();
    let src_stats = remote.stats().source.expect("http transport reports fetch stats");
    assert!(src_stats.bytes_fetched < total, "open must not download the container");

    let mem = PocketReader::from_bytes(bytes).unwrap();
    let a = remote.reconstruct_all(session.runtime()).unwrap();
    let b = mem.reconstruct_all(session.runtime()).unwrap();
    assert_eq!(a.flat, b.flat, "HEAD-less decode diverged from the in-memory path");

    // the wire shows the fallback: a rejected HEAD, then the 0-0 probe
    let log = server.requests();
    assert_eq!((log[0].method.as_str(), log[0].status), ("HEAD", 405));
    let probe = &log[1];
    assert_eq!((probe.method.as_str(), probe.status), ("GET", 206));
    assert_eq!(probe.range, Some((0, 1)), "length probe must ask for bytes=0-0");
    // 405 is permanent: the client must not have retried the HEAD
    assert_eq!(log.iter().filter(|r| r.method == "HEAD").count(), 1);
}

#[test]
fn scripted_405_on_head_also_triggers_the_probe_fallback() {
    // same fallback via the fault scripting (one-shot 405 instead of a
    // permanently GET-only server)
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let bytes = pocket.to_bytes();
    let server = RangeServer::serve(bytes.clone()).unwrap();
    server.push_fault(Fault::Status(405));

    let remote =
        PocketReader::open_url_with(&server.url(), fast_opts()).unwrap();
    let mem = PocketReader::from_bytes(bytes).unwrap();
    let a = remote.reconstruct_all(session.runtime()).unwrap();
    let b = mem.reconstruct_all(session.runtime()).unwrap();
    assert_eq!(a.flat, b.flat);
    let log = server.requests();
    assert_eq!((log[0].method.as_str(), log[0].status), ("HEAD", 405));
    assert_eq!(log[0].fault, Some("status"));
    assert_eq!((log[1].method.as_str(), log[1].status), ("GET", 206));
    assert_eq!(log[1].range, Some((0, 1)));
}
