//! Integration tests for the zero-copy serving core:
//!
//! * N threads hammering `decode_group`/`tensor` on one shared byte-budget
//!   cache produce bit-identical results, never deadlock, and fetch each
//!   group's section from the source **exactly once** (single-flight);
//! * a budget smaller than one decoded group still serves every request —
//!   it just never caches (and the counters say so);
//! * `ChunkedSource` (the hermetic HTTP range-request stand-in): a ranged
//!   open reads only header + TOC chunks, and decoding one group fetches
//!   only that group's ranges;
//! * `MmapSource` decodes bit-identically to the in-memory path;
//! * two readers sharing one `DecodeCache` compete under one byte budget
//!   (cross-reader eviction, no key aliasing);
//! * the `Session::serve` / `PocketServer` layer fans a mixed request list
//!   over worker threads against the shared cache — and dense residue
//!   sections ride the same cache (fetched once, never per request).
//!
//! Everything runs hermetically on the pure-Rust reference backend.
//! The remote streaming path (`HttpSource` + loopback range server) has its
//! own suite in `tests/remote_stream.rs`.

use std::sync::Arc;

use pocketllm::coordinator::reconstruct_from_pocket;
use pocketllm::model::group_rows;
use pocketllm::packfmt::{ChunkedSource, PocketFile, PocketReader};
use pocketllm::serve::ServeRequest;
use pocketllm::session::Session;
use pocketllm::DecodeCache;

mod common;
use common::compressed_pocket;

#[test]
fn concurrent_threads_share_one_fetch_and_decode_per_group() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let reader = Arc::new(PocketReader::from_bytes(pocket.to_bytes()).unwrap());

    // ground truth from the serialized container (codebook goes through f16)
    let direct =
        reconstruct_from_pocket(session.runtime(), &PocketFile::from_bytes(&pocket.to_bytes()).unwrap())
            .unwrap();
    let expect_q = group_rows(&direct, "q").unwrap();
    let expect_up = group_rows(&direct, "up").unwrap();
    let e = direct.cfg.layout.find("b0.wq").unwrap();
    let expect_wq = direct.flat[e.offset..e.offset + e.size].to_vec();

    const THREADS: usize = 8;
    const ITERS: usize = 10;
    std::thread::scope(|s| {
        for _ in 0..THREADS {
            s.spawn(|| {
                let rt = session.runtime();
                for _ in 0..ITERS {
                    let q = reader.decode_group(rt, "q").unwrap();
                    assert_eq!(q.data, expect_q.data, "concurrent decode diverged");
                    let up = reader.decode_group(rt, "up").unwrap();
                    assert_eq!(up.data, expect_up.data);
                    let wq = reader.tensor(rt, "b0.wq").unwrap();
                    assert_eq!(wq, expect_wq);
                }
            });
        }
    });

    let st = reader.stats();
    // the load-bearing claim: 240 decode-path calls, 2 section fetches
    assert_eq!(st.group_sections_read, 2, "a group section was fetched more than once");
    assert_eq!(st.group_decodes, 2, "a group was decoded more than once across threads");
    // every call either decoded or hit the cache (tensor() decodes through
    // its group, so 3 decode-path calls per iteration)
    let calls = (THREADS * ITERS * 3) as u64;
    assert_eq!(st.cache_hits + st.group_decodes, calls);
    // eviction counters consistent: nothing was evicted, both groups resident
    assert_eq!(st.cache.evictions, 0);
    assert_eq!(st.cache.entries, 2);
    let expect_resident = 4 * (expect_q.data.len() + expect_up.data.len()) as u64;
    assert_eq!(st.cache.resident_bytes, expect_resident);
    assert_eq!(st.cache.hits, st.cache_hits);
    assert_eq!(st.cache.misses, st.group_decodes);
}

#[test]
fn budget_smaller_than_one_group_still_serves_but_never_caches() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    // 64 bytes is far below any decoded group in this pocket
    let reader =
        Arc::new(PocketReader::from_bytes(pocket.to_bytes()).unwrap().with_cache_budget(64));
    let direct =
        reconstruct_from_pocket(session.runtime(), &PocketFile::from_bytes(&pocket.to_bytes()).unwrap())
            .unwrap();
    let expect_q = group_rows(&direct, "q").unwrap();

    const THREADS: usize = 8;
    const ITERS: usize = 5;
    std::thread::scope(|s| {
        for _ in 0..THREADS {
            s.spawn(|| {
                for _ in 0..ITERS {
                    let q = reader.decode_group(session.runtime(), "q").unwrap();
                    assert_eq!(q.data, expect_q.data);
                }
            });
        }
    });

    let st = reader.stats();
    let calls = (THREADS * ITERS) as u64;
    assert_eq!(st.group_decodes, calls, "an oversize group must decode every time");
    assert_eq!(st.cache_hits, 0);
    assert_eq!(st.cache.uncacheable, calls);
    assert_eq!(st.cache.resident_bytes, 0);
    assert_eq!(st.cache.entries, 0);
    assert_eq!(st.group_sections_read, calls, "each decode re-reads the section");
}

#[test]
fn chunked_source_open_and_single_decode_fetch_only_their_ranges() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let bytes = pocket.to_bytes();
    let total = bytes.len() as u64;
    let chunk = 256u64;

    let src = ChunkedSource::new(bytes, chunk);
    let reader = PocketReader::with_source(src.clone()).unwrap();

    // a ranged open reads only header + TOC bytes (chunk-rounded)
    let header_cover = reader.header_bytes().div_ceil(chunk) * chunk;
    let open_ranges = src.range_log();
    assert!(!open_ranges.is_empty());
    for (off, len) in &open_ranges {
        assert!(off + len <= header_cover.min(total), "open fetched past the TOC");
    }
    assert!(src.bytes_fetched() < total, "open must not download the container");
    let open_count = open_ranges.len();

    // decoding one group fetches only that group's ranges
    let (q_off, q_len) = reader.section_span("q").unwrap();
    reader.decode_group(session.runtime(), "q").unwrap();
    let log = src.range_log();
    let fetched = &log[open_count..];
    assert!(!fetched.is_empty(), "decode must fetch the group's section");
    let lo = q_off / chunk * chunk;
    let hi = ((q_off + q_len).div_ceil(chunk) * chunk).min(total);
    for (off, len) in fetched {
        assert!(
            *off >= lo && off + len <= hi,
            "range {off}+{len} is outside group q's span [{lo}, {hi})"
        );
    }
    // ... which also means the "up" group and the dense residue (both past
    // q's chunk cover) were never downloaded
    assert!(src.bytes_fetched() < total);

    // a second decode is a cache hit: zero new ranges
    let before = src.ranges_fetched();
    reader.decode_group(session.runtime(), "q").unwrap();
    assert_eq!(src.ranges_fetched(), before, "cache hit re-fetched ranges");

    // the transport counters surface uniformly through ReaderStats
    let fetched = reader.stats().source.expect("chunked transport must report stats");
    assert_eq!(fetched.ranges_fetched, src.ranges_fetched());
    assert_eq!(fetched.bytes_fetched, src.bytes_fetched());
}

#[cfg(unix)]
#[test]
fn mmap_open_decodes_bit_identically_to_memory() {
    use pocketllm::packfmt::MmapSource;
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let bytes = pocket.to_bytes();
    let path = std::env::temp_dir().join("pocketllm_test_mmap_parity.pocket");
    std::fs::write(&path, &bytes).unwrap();

    let via_mmap = PocketReader::with_source(MmapSource::open(&path).unwrap()).unwrap();
    let via_mem = PocketReader::from_bytes(bytes).unwrap();
    let a = via_mmap.reconstruct_all(session.runtime()).unwrap();
    let b = via_mem.reconstruct_all(session.runtime()).unwrap();
    assert_eq!(a.flat, b.flat, "mmap decode diverged from the in-memory path");
    assert_eq!(via_mmap.stats().bytes_read, via_mem.stats().bytes_read);

    // the default open() goes through the mmap/file auto-pick and agrees too
    let via_open = PocketReader::open(&path).unwrap();
    let c = via_open.reconstruct_all(session.runtime()).unwrap();
    assert_eq!(a.flat, c.flat);
    std::fs::remove_file(&path).ok();
}

#[test]
fn two_readers_share_one_cache_under_one_budget() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let bytes: Arc<[u8]> = pocket.to_bytes().into();

    // generous budget: both readers' "q" groups fit side by side
    let probe = PocketReader::from_bytes(bytes.clone()).unwrap();
    let q_bytes = {
        let rows = probe.decode_group(session.runtime(), "q").unwrap();
        4 * rows.data.len() as u64
    };
    let cache = DecodeCache::with_budget(2 * q_bytes);
    let a = PocketReader::from_bytes(bytes.clone()).unwrap().with_shared_cache(cache.clone());
    let b = PocketReader::from_bytes(bytes.clone()).unwrap().with_shared_cache(cache.clone());
    let qa = a.decode_group(session.runtime(), "q").unwrap();
    let qb = b.decode_group(session.runtime(), "q").unwrap();
    assert_eq!(qa.data, qb.data);
    let st = cache.stats();
    // keys are namespaced per reader: same group name, two entries
    assert_eq!(st.entries, 2, "readers must not alias cache keys");
    assert_eq!(st.resident_bytes, 2 * q_bytes);

    // tight budget: the second reader's decode evicts the first's
    let tight = DecodeCache::with_budget(q_bytes);
    let a = PocketReader::from_bytes(bytes.clone()).unwrap().with_shared_cache(tight.clone());
    let b = PocketReader::from_bytes(bytes.clone()).unwrap().with_shared_cache(tight.clone());
    a.decode_group(session.runtime(), "q").unwrap();
    b.decode_group(session.runtime(), "q").unwrap();
    let st = tight.stats();
    assert_eq!(st.evictions, 1, "shared budget must evict across readers");
    assert_eq!(st.entries, 1);
    assert_eq!(st.resident_bytes, q_bytes);
    // reader a's next decode misses again (it was evicted), and works
    let s_before = a.stats().group_decodes;
    a.decode_group(session.runtime(), "q").unwrap();
    assert_eq!(a.stats().group_decodes, s_before + 1);
}

#[test]
fn serve_layer_fans_mixed_requests_over_workers() {
    let session = Session::reference();
    let pocket = compressed_pocket(&session);
    let reader = Arc::new(PocketReader::from_bytes(pocket.to_bytes()).unwrap());

    let mut requests = Vec::new();
    for i in 0..60 {
        requests.push(match i % 3 {
            0 => ServeRequest::Group(if i % 2 == 0 { "q" } else { "up" }.to_string()),
            1 => ServeRequest::Tensor("b0.wq".to_string()),
            _ => ServeRequest::Tensor("b0.wv".to_string()), // dense residue
        });
    }
    requests.push(ServeRequest::Eval { ppl_batches: 1 });

    let report = session.serve(reader.clone()).workers(4).run(&requests).unwrap();
    assert_eq!(report.requests, requests.len());
    assert_eq!(report.workers, 4);
    assert!(report.rps() > 0.0);
    let st = reader.stats();
    assert_eq!(st.group_sections_read, 2, "each group section fetched exactly once");
    assert_eq!(st.group_decodes, 2);
    assert!(report.cache_hit_rate() > 0.5, "warm serving must mostly hit the cache");
    // dense residue rides the same shared cache: every dense section is
    // fetched at most once across all 20 b0.wv requests + the eval probe
    // (which reconstructs through the reader), never once per request
    assert_eq!(
        st.dense_sections_read,
        reader.dense_names().len() as u64,
        "a dense residue section was re-read"
    );
    assert!(st.dense_hits >= 19, "warm dense requests must hit the cache: {st:?}");
    // in-memory source: no range-transport counters
    assert!(st.source.is_none());

    // unknown names surface as typed errors, not hangs
    let err = session
        .serve(reader)
        .workers(2)
        .run(&[ServeRequest::Group("nope".into())])
        .unwrap_err();
    assert!(matches!(err, pocketllm::Error::UnknownGroup { .. }), "{err:?}");
}
