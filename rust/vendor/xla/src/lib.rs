//! API-compatible **stub** of the `xla` crate surface that PocketLLM's PJRT
//! backend (`pocketllm::runtime::pjrt`) touches.
//!
//! The real crate links `libxla_extension` (hundreds of MB of native code)
//! and cannot be vendored into a hermetic checkout.  This stub keeps the
//! PJRT code path *compiling* everywhere while making its unavailability a
//! clean runtime error: [`PjRtClient::cpu`] always fails, so
//! `Runtime::pjrt(..)` reports "PJRT unavailable" and the coordinator falls
//! back to the pure-Rust reference backend.
//!
//! To run against real XLA artifacts, replace the `xla = { path = ... }`
//! dependency in `rust/Cargo.toml` with the real bindings; the API below is
//! the exact subset the backend calls.

use std::fmt;

/// Error type mirroring the real crate's (anyhow-compatible: implements
/// `std::error::Error`).
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what}: PJRT/XLA is not available in this build (rust/vendor/xla is \
         the hermetic stub; swap it for the real xla crate to enable the \
         PJRT backend)"
    )))
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host-side literal (stub: carries no data; never observed at runtime
/// because client construction fails first).
#[derive(Clone, Debug)]
pub struct Literal {
    _shape: Vec<i64>,
}

impl Literal {
    pub fn scalar(_x: f32) -> Literal {
        Literal { _shape: vec![] }
    }

    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { _shape: vec![v.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _shape: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the stub's choke point: it
/// fails before any artifact is touched, so callers degrade gracefully.
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn literals_marshal_without_runtime() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
        let _ = Literal::vec1(&[1i32, 2, 3]);
        let _ = Literal::scalar(0.5);
    }
}
